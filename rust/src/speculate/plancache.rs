//! Content-addressed cache of compiled co-execution plans.
//!
//! Keyed by the canonical [`GraphSig`](crate::speculate::GraphSig) of the
//! merged TraceGraph plus the plan-shaping knobs (`fusion`, `opt_level`). A
//! hit hands back the `Arc` of a previously compiled plan — optimized graph,
//! generated `PlanSpec` and compiled segments included — so re-entering
//! co-execution skips the optimizer pipeline, plan generation and every
//! segment compilation; only the GraphRunner thread is respawned.
//!
//! The cache is **process-global** (like [`crate::runtime::ExecCache`]):
//! within one engine the merged graph only ever grows, so a signature never
//! recurs; the repeat customers are *other engine instances of the same
//! program* — re-runs in a bench loop, the serving scenario where many
//! short-lived engines execute one model, and each re-run's own
//! fallback→re-entry cycles, which replay the same signature sequence. A
//! signature match pins the full indexed structure (see `signature.rs`), so
//! NodeIds, case indices and variant indices of the cached plan line up with
//! the new engine's graph.

use crate::symbolic::CompiledPlan;
use crate::tracegraph::NodeId;
use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::GraphSig;

/// Full cache key: graph signature + the knobs that shape the plan + the
/// execution backend the segments were compiled for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub sig: GraphSig,
    /// Whole-segment fusion on/off (the ±XLA axis) changes segmentation.
    pub fusion: bool,
    /// Graph-optimization level changes the plan-side graph.
    pub opt_level: u8,
    /// Resolved shim backend (`XLA_SHIM_BACKEND`). The env var can differ
    /// between the process that populated the cache entry and the one
    /// looking it up (interp CI job, differential tests), and a cached plan
    /// holds executables compiled for one backend only.
    pub backend: xla::ShimBackend,
    /// Order-independent hash of the segment split-point set (profile-guided
    /// splitting changes segmentation the same way `fusion` does).
    pub splits: u64,
}

/// FNV-1a over the sorted split set; stable across processes so identical
/// profiles key identically. The empty set hashes to the FNV offset basis.
pub fn splits_hash(splits: &BTreeSet<NodeId>) -> u64 {
    use crate::trace::{FNV_OFFSET, FNV_PRIME};
    let mut h: u64 = FNV_OFFSET;
    for n in splits {
        for b in (n.0 as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

impl PlanKey {
    /// Build a key for the current process state: resolves the active shim
    /// backend and hashes the split set.
    pub fn new(sig: GraphSig, fusion: bool, opt_level: u8, splits: &BTreeSet<NodeId>) -> Self {
        PlanKey {
            sig,
            fusion,
            opt_level,
            backend: xla::active_backend(),
            splits: splits_hash(splits),
        }
    }
}

/// A cached plan plus the compile work a hit skips.
#[derive(Clone)]
pub struct CachedPlan {
    pub plan: Arc<CompiledPlan>,
    /// Non-empty compiled segments in the plan.
    pub segments: u64,
    /// Op nodes compiled into those segments.
    pub segment_nodes: u64,
}

struct Inner {
    map: HashMap<PlanKey, Entry>,
    tick: u64,
}

struct Entry {
    cached: CachedPlan,
    last_used: u64,
}

/// Bounded, LRU-evicting plan cache with cross-request build coalescing:
/// when several sessions miss on the same key concurrently, exactly one
/// (the *lead*, picked by [`PlanCache::begin_build`]) compiles while the
/// others wait on a [`BuildLease`] and receive the same `Arc` — one
/// compile, all waiters served.
pub struct PlanCache {
    inner: Mutex<Inner>,
    /// In-flight coalesced builds, keyed like the cache itself. An entry
    /// exists from the lead's `begin_build` until its ticket fulfills or
    /// drops; followers found here wait instead of compiling.
    building: Mutex<HashMap<PlanKey, Arc<BuildLease>>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    coalesced: AtomicU64,
}

/// Capacity from a raw `TERRA_PLAN_CACHE_CAP` value: absent = 64, `>= 1`
/// accepted, anything else (junk, zero) a hard error — the seed silently
/// fell back to 64 on `TERRA_PLAN_CACHE_CAP=0`.
fn capacity_from_raw(raw: Option<&str>) -> crate::error::Result<usize> {
    Ok(crate::config::env::value_min("TERRA_PLAN_CACHE_CAP", raw, 1)?.unwrap_or(64))
}

fn default_capacity() -> usize {
    capacity_from_raw(std::env::var("TERRA_PLAN_CACHE_CAP").ok().as_deref())
        .unwrap_or_else(|e| panic!("{e}"))
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::with_capacity(default_capacity())
    }
}

impl PlanCache {
    pub fn with_capacity(capacity: usize) -> Self {
        PlanCache {
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            building: Mutex::new(HashMap::new()),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }

    /// Process-wide cache (capacity from `TERRA_PLAN_CACHE_CAP`, default 64).
    pub fn global() -> &'static Arc<PlanCache> {
        static GLOBAL: OnceLock<Arc<PlanCache>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(PlanCache::default()))
    }

    /// Look up a plan, counting a hit or miss and refreshing LRU order.
    pub fn lookup(&self, key: &PlanKey) -> Option<CachedPlan> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.cached.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Membership probe without touching hit/miss counters or LRU order
    /// (used by the re-entry controller to decide whether entering is free).
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.inner.lock().unwrap().map.contains_key(key)
    }

    /// Insert a compiled plan, evicting the least-recently-used entry when
    /// over capacity.
    pub fn insert(&self, key: PlanKey, plan: Arc<CompiledPlan>) {
        let segments = plan.segments.iter().filter(|s| !s.spec.nodes.is_empty()).count() as u64;
        let segment_nodes: u64 = plan.segments.iter().map(|s| s.spec.nodes.len() as u64).sum();
        let cached = CachedPlan { plan, segments, segment_nodes };
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if inner.map.insert(key, Entry { cached, last_used: tick }).is_none() {
            self.insertions.fetch_add(1, Ordering::Relaxed);
        }
        while inner.map.len() > self.capacity {
            let Some(victim) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            else {
                break;
            };
            inner.map.remove(&victim);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Evict one entry (a plan that faulted at runtime: the cached
    /// executables are suspect, the next admission recompiles from the
    /// trace). Returns whether the key was present.
    pub fn remove(&self, key: &PlanKey) -> bool {
        self.inner.lock().unwrap().map.remove(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn insertions(&self) -> u64 {
        self.insertions.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Plan builds avoided by coalescing: requests served a plan another
    /// request was already compiling (or had just inserted).
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// Claim (or join) the in-flight build for `key` after a cache miss.
    ///
    /// * [`BuildRole::Lead`]: no one is building — the caller must compile
    ///   and [`BuildTicket::fulfill`] (dropping the ticket unfulfilled, e.g.
    ///   on a panic or error, fails the lease and wakes the waiters so they
    ///   self-build).
    /// * [`BuildRole::Follow`]: another request holds the lease; wait on it
    ///   with [`PlanCache::await_build`].
    /// * [`BuildRole::Ready`]: the plan landed in the cache between the
    ///   caller's miss and this call — counted as coalesced, no compile.
    pub fn begin_build(&self, key: PlanKey) -> BuildRole<'_> {
        let mut building = self.building.lock().unwrap();
        if let Some(lease) = building.get(&key) {
            return BuildRole::Follow(lease.clone());
        }
        // Re-check the cache under the building lock: the previous lead may
        // have fulfilled (insert + lease removal) since the caller's miss.
        if let Some(e) = self.inner.lock().unwrap().map.get(&key) {
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            return BuildRole::Ready(e.cached.clone());
        }
        let lease = Arc::new(BuildLease {
            state: Mutex::new(LeaseState::Building),
            cv: Condvar::new(),
        });
        building.insert(key, lease.clone());
        BuildRole::Lead(BuildTicket { cache: self, key, lease, fulfilled: false })
    }

    /// Wait (bounded) for a lead's build. `Some` means the lease was
    /// fulfilled and this request coalesced onto it; `None` (failed lease or
    /// timeout) means the caller should build for itself.
    pub fn await_build(&self, lease: &BuildLease, timeout: Duration) -> Option<CachedPlan> {
        let got = lease.wait(timeout);
        if got.is_some() {
            self.coalesced.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// In-flight coalesced builds right now (tests / stats).
    pub fn building_len(&self) -> usize {
        self.building.lock().unwrap().len()
    }
}

/// Outcome of [`PlanCache::begin_build`].
pub enum BuildRole<'a> {
    /// Caller owns the build; fulfill or drop the ticket.
    Lead(BuildTicket<'a>),
    /// Another request is building; wait via [`PlanCache::await_build`].
    Follow(Arc<BuildLease>),
    /// The plan is already cached (raced with a fulfilling lead).
    Ready(CachedPlan),
}

enum LeaseState {
    Building,
    Done(CachedPlan),
    Failed,
}

/// Shared wait-point for one in-flight plan build (one per key at a time).
pub struct BuildLease {
    state: Mutex<LeaseState>,
    cv: Condvar,
}

impl BuildLease {
    fn wait(&self, timeout: Duration) -> Option<CachedPlan> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            match &*st {
                LeaseState::Done(c) => return Some(c.clone()),
                LeaseState::Failed => return None,
                LeaseState::Building => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _res) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    fn settle(&self, state: LeaseState) {
        *self.state.lock().unwrap() = state;
        self.cv.notify_all();
    }
}

/// The lead builder's obligation: exactly one exists per in-flight key.
/// [`fulfill`](BuildTicket::fulfill) inserts the plan into the cache and
/// wakes every waiter with it; dropping the ticket without fulfilling
/// (error or panic paths) fails the lease — waiters fall back to building
/// for themselves, so a crashed lead can never wedge its followers.
pub struct BuildTicket<'a> {
    cache: &'a PlanCache,
    key: PlanKey,
    lease: Arc<BuildLease>,
    fulfilled: bool,
}

impl BuildTicket<'_> {
    /// The key this ticket is building.
    pub fn key(&self) -> &PlanKey {
        &self.key
    }

    /// Publish the built plan: cache insert, lease fulfilment, waiter
    /// wake-up — in that order, so a waiter that times out right here still
    /// finds the plan in the cache.
    pub fn fulfill(mut self, plan: Arc<CompiledPlan>) {
        self.cache.insert(self.key, plan);
        let cached = self
            .cache
            .lookup_quiet(&self.key)
            .expect("a just-inserted plan must be present");
        self.cache.building.lock().unwrap().remove(&self.key);
        self.lease.settle(LeaseState::Done(cached));
        self.fulfilled = true;
    }
}

impl Drop for BuildTicket<'_> {
    fn drop(&mut self) {
        if !self.fulfilled {
            self.cache.building.lock().unwrap().remove(&self.key);
            self.lease.settle(LeaseState::Failed);
        }
    }
}

impl PlanCache {
    /// Internal lookup that touches neither counters nor LRU order.
    fn lookup_quiet(&self, key: &PlanKey) -> Option<CachedPlan> {
        self.inner.lock().unwrap().map.get(key).map(|e| e.cached.clone())
    }
}

/// Verdict of a quarantine admission check before entering co-execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuarantineVerdict {
    /// No (remaining) suspicion: co-execution may be entered.
    Allow,
    /// The plan faulted recently; this entry attempt is skipped as part of
    /// its exponential backoff (the engine stays in tracing and retries on
    /// a later stable trace, recompiling from scratch).
    Backoff,
    /// `TERRA_PLAN_MAX_FAULTS` strikes accumulated: the plan is pinned to
    /// eager execution for the rest of the process.
    Quarantined,
}

struct QuarantineEntry {
    strikes: u32,
    /// Entry attempts still to skip before the next recompile is allowed.
    skip: u64,
}

/// Per-plan fault registry: the retry/backoff/quarantine brain of the fault
/// degradation ladder (see `speculate/README.md`).
///
/// Every symbolic fault attributed to a plan key is a *strike*. After
/// strike `n` (1-based) the next `2^n` co-execution entry attempts for that
/// key are skipped (exponential backoff; each retry recompiles, because the
/// fault fallback also evicts the key from the [`PlanCache`]). At
/// `TERRA_PLAN_MAX_FAULTS` strikes (default 3, minimum 1) the key is
/// quarantined: pinned to eager/tracing execution for the process lifetime.
///
/// Process-global by default (like the plan cache: the repeat customers are
/// re-runs of the same signature), with per-engine instances available for
/// test isolation ([`Engine::set_quarantine`](crate::runner::Engine)).
pub struct Quarantine {
    inner: Mutex<HashMap<PlanKey, QuarantineEntry>>,
    max_faults: u32,
}

/// Strike limit from a raw `TERRA_PLAN_MAX_FAULTS` value: absent = 3,
/// `>= 1` accepted, junk or zero a hard error naming the knob.
fn max_faults_from_raw(raw: Option<&str>) -> crate::error::Result<u32> {
    Ok(crate::config::env::value_min("TERRA_PLAN_MAX_FAULTS", raw, 1)?.unwrap_or(3))
}

impl Quarantine {
    pub fn with_max_faults(max_faults: u32) -> Self {
        Quarantine { inner: Mutex::new(HashMap::new()), max_faults: max_faults.max(1) }
    }

    /// Strike limit from `TERRA_PLAN_MAX_FAULTS` (strict parse).
    pub fn from_env() -> crate::error::Result<Self> {
        let raw = std::env::var("TERRA_PLAN_MAX_FAULTS").ok();
        Ok(Self::with_max_faults(max_faults_from_raw(raw.as_deref())?))
    }

    /// Process-wide registry.
    pub fn global() -> &'static Arc<Quarantine> {
        static GLOBAL: OnceLock<Arc<Quarantine>> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            Arc::new(Quarantine::from_env().unwrap_or_else(|e| panic!("{e}")))
        })
    }

    pub fn max_faults(&self) -> u32 {
        self.max_faults
    }

    /// Admission check before a co-execution entry for `key`. `Backoff`
    /// consumes one skipped attempt.
    pub fn admit(&self, key: &PlanKey) -> QuarantineVerdict {
        let mut inner = self.inner.lock().unwrap();
        match inner.get_mut(key) {
            None => QuarantineVerdict::Allow,
            Some(e) if e.strikes >= self.max_faults => QuarantineVerdict::Quarantined,
            Some(e) if e.skip > 0 => {
                e.skip -= 1;
                QuarantineVerdict::Backoff
            }
            Some(_) => QuarantineVerdict::Allow,
        }
    }

    /// Record a symbolic fault attributed to `key`. Returns `true` iff this
    /// strike is the one that quarantined the key (so callers can count
    /// quarantine *events* exactly once).
    pub fn strike(&self, key: PlanKey) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let e = inner.entry(key).or_insert(QuarantineEntry { strikes: 0, skip: 0 });
        e.strikes += 1;
        let deciding = if e.strikes >= self.max_faults {
            e.skip = 0;
            e.strikes == self.max_faults
        } else {
            e.skip = 1u64 << e.strikes.min(32);
            false
        };
        crate::obs::instant(
            crate::obs::Track::Engine,
            crate::obs::InstantKind::QuarantineStrike,
            0,
            e.strikes as u64,
            deciding as u64,
        );
        deciding
    }

    pub fn strikes(&self, key: &PlanKey) -> u32 {
        self.inner.lock().unwrap().get(key).map_or(0, |e| e.strikes)
    }

    pub fn is_quarantined(&self, key: &PlanKey) -> bool {
        self.inner.lock().unwrap().get(key).is_some_and(|e| e.strikes >= self.max_faults)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::CompiledPlan;
    use crate::tracegraph::TraceGraph;

    fn key(n: u64) -> PlanKey {
        PlanKey::new(GraphSig { a: n, b: !n }, true, 2, &BTreeSet::new())
    }

    fn empty_plan() -> Arc<CompiledPlan> {
        Arc::new(CompiledPlan {
            steps: vec![],
            segments: vec![],
            graph: Arc::new(TraceGraph::new()),
            compiled_fresh: 0,
            split_points: vec![],
        })
    }

    #[test]
    fn hit_miss_accounting() {
        let c = PlanCache::with_capacity(4);
        assert!(c.lookup(&key(1)).is_none());
        assert_eq!(c.misses(), 1);
        c.insert(key(1), empty_plan());
        assert!(c.lookup(&key(1)).is_some());
        assert_eq!(c.hits(), 1);
        assert!(c.contains(&key(1)));
        // `contains` counts nothing.
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn knobs_partition_the_key_space() {
        let c = PlanCache::with_capacity(8);
        let sig = GraphSig { a: 7, b: 9 };
        let base = PlanKey::new(sig, true, 2, &BTreeSet::new());
        c.insert(base, empty_plan());
        assert!(!c.contains(&PlanKey { fusion: false, ..base }));
        assert!(!c.contains(&PlanKey { opt_level: 0, ..base }));
        assert!(c.contains(&base));
    }

    #[test]
    fn backend_and_splits_partition_the_key_space() {
        let c = PlanCache::with_capacity(8);
        let sig = GraphSig { a: 3, b: 4 };
        let splits: BTreeSet<NodeId> = [NodeId(7), NodeId(2)].into_iter().collect();
        let split_key = PlanKey::new(sig, true, 2, &splits);
        c.insert(split_key, empty_plan());
        // A different (or empty) split set is a different plan shape.
        assert!(!c.contains(&PlanKey::new(sig, true, 2, &BTreeSet::new())));
        let fewer: BTreeSet<NodeId> = [NodeId(7)].into_iter().collect();
        assert!(!c.contains(&PlanKey::new(sig, true, 2, &fewer)));
        assert!(c.contains(&PlanKey::new(sig, true, 2, &splits)));
        // Executables compiled under one shim backend must never serve the
        // other backend's lookups.
        let other = match split_key.backend {
            xla::ShimBackend::Bytecode => xla::ShimBackend::Interp,
            xla::ShimBackend::Interp => xla::ShimBackend::Bytecode,
        };
        assert!(!c.contains(&PlanKey { backend: other, ..split_key }));
    }

    #[test]
    fn splits_hash_is_order_independent_and_value_sensitive() {
        let a: BTreeSet<NodeId> = [NodeId(1), NodeId(9), NodeId(4)].into_iter().collect();
        let b: BTreeSet<NodeId> = [NodeId(9), NodeId(4), NodeId(1)].into_iter().collect();
        assert_eq!(splits_hash(&a), splits_hash(&b));
        let c: BTreeSet<NodeId> = [NodeId(1), NodeId(9)].into_iter().collect();
        assert_ne!(splits_hash(&a), splits_hash(&c));
        assert_ne!(splits_hash(&a), splits_hash(&BTreeSet::new()));
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let c = PlanCache::with_capacity(2);
        c.insert(key(1), empty_plan());
        c.insert(key(2), empty_plan());
        let _ = c.lookup(&key(1)); // refresh 1: victim becomes 2
        c.insert(key(3), empty_plan());
        assert_eq!(c.len(), 2);
        assert!(c.contains(&key(1)));
        assert!(!c.contains(&key(2)));
        assert!(c.contains(&key(3)));
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn remove_evicts_a_faulted_plan() {
        let c = PlanCache::with_capacity(4);
        c.insert(key(1), empty_plan());
        assert!(c.remove(&key(1)));
        assert!(!c.contains(&key(1)));
        assert!(!c.remove(&key(1)), "double eviction is a no-op");
    }

    #[test]
    fn quarantine_ladder_backoff_then_pin() {
        let q = Quarantine::with_max_faults(3);
        let k = key(9);
        assert_eq!(q.admit(&k), QuarantineVerdict::Allow);
        // Strike 1: skip the next 2 entry attempts, then allow a retry.
        assert!(!q.strike(k));
        assert_eq!(q.admit(&k), QuarantineVerdict::Backoff);
        assert_eq!(q.admit(&k), QuarantineVerdict::Backoff);
        assert_eq!(q.admit(&k), QuarantineVerdict::Allow);
        // Strike 2: skip 4.
        assert!(!q.strike(k));
        for _ in 0..4 {
            assert_eq!(q.admit(&k), QuarantineVerdict::Backoff);
        }
        assert_eq!(q.admit(&k), QuarantineVerdict::Allow);
        // Strike 3 = TERRA_PLAN_MAX_FAULTS: quarantined, exactly once.
        assert!(q.strike(k));
        assert!(q.is_quarantined(&k));
        assert_eq!(q.admit(&k), QuarantineVerdict::Quarantined);
        assert_eq!(q.admit(&k), QuarantineVerdict::Quarantined);
        // Further strikes (e.g. a racing engine) do not re-count the event.
        assert!(!q.strike(k));
        assert_eq!(q.strikes(&k), 4);
        // Other keys are unaffected.
        assert_eq!(q.admit(&key(10)), QuarantineVerdict::Allow);
    }

    #[test]
    fn quarantine_max_faults_one_pins_on_first_strike() {
        let q = Quarantine::with_max_faults(1);
        let k = key(2);
        assert!(q.strike(k));
        assert_eq!(q.admit(&k), QuarantineVerdict::Quarantined);
    }

    #[test]
    fn max_faults_env_knob_rejects_junk_and_zero() {
        assert_eq!(max_faults_from_raw(None).unwrap(), 3);
        assert_eq!(max_faults_from_raw(Some("1")).unwrap(), 1);
        let e = max_faults_from_raw(Some("0")).unwrap_err();
        assert!(e.to_string().contains("TERRA_PLAN_MAX_FAULTS"), "{e}");
        let e = max_faults_from_raw(Some("many")).unwrap_err();
        assert!(e.to_string().contains("TERRA_PLAN_MAX_FAULTS"), "{e}");
    }

    #[test]
    fn coalesced_build_one_lead_many_followers() {
        let c = Arc::new(PlanCache::with_capacity(4));
        let k = key(21);
        // First claim is the lead.
        let ticket = match c.begin_build(k) {
            BuildRole::Lead(t) => t,
            _ => panic!("first begin_build must lead"),
        };
        assert_eq!(c.building_len(), 1);
        // Concurrent claims follow and block until the lead fulfills.
        let waiters: Vec<_> = (0..3)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || match c.begin_build(k) {
                    BuildRole::Follow(lease) => {
                        c.await_build(&lease, Duration::from_secs(10)).is_some()
                    }
                    BuildRole::Ready(_) => true,
                    BuildRole::Lead(_) => false,
                })
            })
            .collect();
        // Give the waiters a moment to park on the lease, then publish.
        std::thread::sleep(Duration::from_millis(20));
        ticket.fulfill(empty_plan());
        for w in waiters {
            assert!(w.join().unwrap(), "every waiter must be served the lead's plan");
        }
        assert_eq!(c.building_len(), 0);
        assert!(c.contains(&k));
        assert!(c.coalesced() >= 3, "got {}", c.coalesced());
        // A late request finds the plan cached — a plain hit, not a lease.
        assert!(c.lookup(&k).is_some());
    }

    #[test]
    fn dropped_ticket_fails_the_lease_and_waiters_self_build() {
        let c = Arc::new(PlanCache::with_capacity(4));
        let k = key(22);
        let ticket = match c.begin_build(k) {
            BuildRole::Lead(t) => t,
            _ => panic!("must lead"),
        };
        let lease = match c.begin_build(k) {
            BuildRole::Follow(l) => l,
            _ => panic!("second claim must follow"),
        };
        drop(ticket); // lead dies without fulfilling (build error / panic)
        assert!(
            c.await_build(&lease, Duration::from_secs(10)).is_none(),
            "a failed lease must release waiters empty-handed"
        );
        assert_eq!(c.building_len(), 0, "the dead lead's lease must be unpublished");
        // The key is claimable again: the former waiter becomes the lead.
        match c.begin_build(k) {
            BuildRole::Lead(t) => t.fulfill(empty_plan()),
            _ => panic!("retry after a failed lease must lead"),
        }
        assert!(c.contains(&k));
    }

    #[test]
    fn begin_build_after_fulfil_returns_ready() {
        let c = PlanCache::with_capacity(4);
        let k = key(23);
        match c.begin_build(k) {
            BuildRole::Lead(t) => t.fulfill(empty_plan()),
            _ => panic!("must lead"),
        }
        // A request that missed before the fulfil but claims after it gets
        // the cached plan straight from the claim, counted as coalesced.
        let before = c.coalesced();
        match c.begin_build(k) {
            BuildRole::Ready(_) => {}
            _ => panic!("cached key must resolve Ready"),
        }
        assert_eq!(c.coalesced(), before + 1);
    }

    #[test]
    fn await_build_times_out_on_a_stuck_lead() {
        let c = PlanCache::with_capacity(4);
        let k = key(24);
        let _ticket = match c.begin_build(k) {
            BuildRole::Lead(t) => t,
            _ => panic!("must lead"),
        };
        let lease = match c.begin_build(k) {
            BuildRole::Follow(l) => l,
            _ => panic!("must follow"),
        };
        let t0 = Instant::now();
        assert!(c.await_build(&lease, Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn capacity_env_knob_rejects_junk_and_zero() {
        assert_eq!(capacity_from_raw(None).unwrap(), 64);
        assert_eq!(capacity_from_raw(Some("8")).unwrap(), 8);
        let e = capacity_from_raw(Some("0")).unwrap_err();
        assert!(e.to_string().contains("TERRA_PLAN_CACHE_CAP"), "{e}");
        let e = capacity_from_raw(Some("abc")).unwrap_err();
        assert!(e.to_string().contains("TERRA_PLAN_CACHE_CAP"), "{e}");
    }
}
