//! Canonical structural signature of a [`TraceGraph`].
//!
//! The plan cache (see [`crate::speculate::plancache`]) is content-addressed:
//! two graphs with equal signatures must be interchangeable as the *symbolic*
//! side of a co-execution phase. Because every runner↔runner message is keyed
//! by `NodeId` plus child-/variant-list **indices** (the wire format, see
//! `opt/README.md`), the signature hashes the fully *indexed* structure —
//! nodes in id order, children and variants in list order — not just the
//! shape of the DAG. A signature match therefore guarantees that a cached
//! plan's NodeIds, case indices and variant indices line up with the current
//! engine graph.
//!
//! Canonicalization (where observation order is incidental, it is erased):
//!
//! * **Generalized consts**: a const node observed with several values is a
//!   feed; which value happened to be observed *first* (its `value_hash` and
//!   stored `const_value`) is an accident of data order and is excluded.
//! * **Variable bindings**: referenced variables are hashed as a `VarId`-
//!   sorted list of `(id, type)` pairs, independent of the map's iteration
//!   order.
//!
//! Everything a compiled plan depends on is included: op defs (kind,
//! attributes, input types via `ItemKey`), program locations, non-generalized
//! const values (via `value_hash` — they are embedded into compiled
//! segments), output types, edges, dataflow variants, and the types of every
//! referenced variable.
//!
//! **Gradient graphs need no special casing.** The tape emits backward ops
//! into the active trace session in fixed reverse-program order under
//! deterministic scopes (`tape`, `g{idx}`), and the optimizers emit staged
//! updates under deterministic scopes (`sgd{i}` / `adam` / `p{i}`) — see
//! `src/tape/README.md`. A train step's merged trace is therefore already
//! canonical: identical train steps hash identically across iterations *and*
//! sessions (cross-session gradient-plan cache hits), while hyperparameters
//! (lr, betas) re-key through non-generalized const `value_hash`es and
//! parameter shapes re-key through the variable `(id, type)` list. Pinned by
//! `tests/speculate_integration.rs::gradient_graph_signature_is_stable_across_sessions`.

use crate::tensor::TensorType;
use crate::tracegraph::{GraphSrc, NodeKind, TraceGraph};
use crate::trace::{ItemKey, VarId};
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// A 128-bit structural signature (two independent FNV streams, so accidental
/// collisions need to defeat both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GraphSig {
    pub a: u64,
    pub b: u64,
}

impl std::fmt::Display for GraphSig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}{:016x}", self.a, self.b)
    }
}

use crate::trace::{FNV_OFFSET, FNV_PRIME};

/// Offset basis of the second (independent) stream; the first stream uses
/// the project-wide [`FNV_OFFSET`].
const FNV_OFFSET_B: u64 = 0x6c62272e07bb0142;

/// Dependency-free [`Hasher`] feeding two FNV-1a streams with different
/// offset bases (stream B additionally whitens each byte), so `#[derive(Hash)]`
/// impls of the graph's component types can be reused directly.
struct SigHasher {
    a: u64,
    b: u64,
}

impl SigHasher {
    fn new() -> Self {
        SigHasher { a: FNV_OFFSET, b: FNV_OFFSET_B }
    }

    fn sig(&self) -> GraphSig {
        GraphSig { a: self.a, b: self.b }
    }
}

impl Hasher for SigHasher {
    fn write(&mut self, bytes: &[u8]) {
        for &byte in bytes {
            self.a = (self.a ^ byte as u64).wrapping_mul(FNV_PRIME);
            self.b = (self.b ^ (byte ^ 0xa5) as u64).wrapping_mul(FNV_PRIME);
        }
    }

    fn finish(&self) -> u64 {
        self.a
    }
}

/// Compute the canonical signature of `graph` plus the bindings of every
/// variable it references. `var_types` is the engine's variable-store type
/// map; unreferenced entries do not influence the signature.
pub fn graph_signature(
    graph: &TraceGraph,
    var_types: &HashMap<VarId, TensorType>,
) -> GraphSig {
    let mut h = SigHasher::new();
    graph.nodes.len().hash(&mut h);
    let mut vars: Vec<VarId> = Vec::new();
    for node in &graph.nodes {
        // Node identity. For generalized consts, erase the first-observed
        // value: only type + location (+ the generalized flag below) matter.
        match &node.kind {
            NodeKind::Start => 0u8.hash(&mut h),
            NodeKind::End => 1u8.hash(&mut h),
            NodeKind::Item(key) => {
                2u8.hash(&mut h);
                match key {
                    ItemKey::Const { ty, loc, .. } if node.generalized => {
                        3u8.hash(&mut h);
                        ty.hash(&mut h);
                        loc.hash(&mut h);
                    }
                    k => k.hash(&mut h),
                }
            }
        }
        node.generalized.hash(&mut h);
        node.removed.hash(&mut h);
        // Execution-order edges and dataflow variants, in list order: the
        // indices are the runner wire format (Case/Variant Selects).
        node.children.hash(&mut h);
        node.variants.hash(&mut h);
        node.out_types.hash(&mut h);
        for variant in &node.variants {
            for src in variant {
                if let GraphSrc::Var(v) = src {
                    vars.push(*v);
                }
            }
        }
        if let NodeKind::Item(ItemKey::Assign { var, .. }) = &node.kind {
            vars.push(*var);
        }
    }
    // Variable bindings, VarId-sorted + deduped (reference multiplicity and
    // map iteration order are incidental).
    vars.sort();
    vars.dedup();
    vars.len().hash(&mut h);
    for v in vars {
        v.hash(&mut h);
        match var_types.get(&v) {
            Some(ty) => {
                1u8.hash(&mut h);
                ty.hash(&mut h);
            }
            None => 0u8.hash(&mut h),
        }
    }
    h.sig()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{OpDef, OpKind};
    use crate::tensor::HostTensor;
    use crate::trace::{FeedKind, Location, Trace, TraceItem, ValueId, ValueRef};

    fn loc(line: u32) -> Location {
        Location { file: "sig.rs", line, col: 1, scope: 0 }
    }

    fn feed(id: u64, line: u32) -> TraceItem {
        TraceItem::Feed {
            id: ValueId(id),
            ty: TensorType::f32(&[2]),
            loc: loc(line),
            kind: FeedKind::Data,
        }
    }

    fn op(kind: OpKind, inp: u64, out: u64, line: u32) -> TraceItem {
        TraceItem::Op {
            def: OpDef::new(kind, vec![TensorType::f32(&[2])]),
            loc: loc(line),
            inputs: vec![ValueRef::Out(ValueId(inp))],
            outputs: vec![ValueId(out)],
        }
    }

    fn konst(v: f32, line: u32) -> TraceItem {
        TraceItem::Const { id: ValueId(1), value: HostTensor::scalar_f32(v), loc: loc(line) }
    }

    fn tr(items: Vec<TraceItem>) -> Trace {
        Trace::resolve(items, 0).unwrap()
    }

    fn sig(g: &TraceGraph) -> GraphSig {
        graph_signature(g, &HashMap::new())
    }

    #[test]
    fn identical_merge_histories_agree() {
        let t = tr(vec![feed(1, 1), op(OpKind::Relu, 1, 2, 2), op(OpKind::Neg, 2, 3, 3)]);
        let mut g1 = TraceGraph::new();
        let mut g2 = TraceGraph::new();
        g1.merge(&t).unwrap();
        g2.merge(&t).unwrap();
        assert_eq!(sig(&g1), sig(&g2));
        // Re-merging a covered trace leaves the signature unchanged.
        let before = sig(&g1);
        g1.merge(&t).unwrap();
        assert_eq!(before, sig(&g1));
    }

    #[test]
    fn structure_changes_change_the_signature() {
        let base = tr(vec![feed(1, 1), op(OpKind::Relu, 1, 2, 2)]);
        let mut g = TraceGraph::new();
        g.merge(&base).unwrap();
        let s0 = sig(&g);
        // New branch.
        g.merge(&tr(vec![feed(1, 1), op(OpKind::Tanh, 1, 2, 3)])).unwrap();
        let s1 = sig(&g);
        assert_ne!(s0, s1);
        // Different op kind at the same site is a different graph.
        let mut h = TraceGraph::new();
        h.merge(&tr(vec![feed(1, 1), op(OpKind::Neg, 1, 2, 2)])).unwrap();
        assert_ne!(s0, sig(&h));
        // Different location, same ops: still a different graph (locations
        // are part of node identity).
        let mut l = TraceGraph::new();
        l.merge(&tr(vec![feed(1, 1), op(OpKind::Relu, 1, 2, 9)])).unwrap();
        assert_ne!(s0, sig(&l));
    }

    #[test]
    fn const_value_matters_until_generalized() {
        let mut g1 = TraceGraph::new();
        g1.merge(&tr(vec![konst(1.0, 5), op(OpKind::Relu, 1, 2, 6)])).unwrap();
        let mut g2 = TraceGraph::new();
        g2.merge(&tr(vec![konst(2.0, 5), op(OpKind::Relu, 1, 2, 6)])).unwrap();
        // Embedded constants compile into segments: values must distinguish.
        assert_ne!(sig(&g1), sig(&g2));
    }

    #[test]
    fn generalized_const_is_order_independent() {
        // Observation order 1.0-then-2.0 vs 2.0-then-1.0 yields nodes whose
        // first-observed value differs, but both are feeds now — canonical
        // signatures must agree.
        let mut g12 = TraceGraph::new();
        g12.merge(&tr(vec![konst(1.0, 5), op(OpKind::Relu, 1, 2, 6)])).unwrap();
        g12.merge(&tr(vec![konst(2.0, 5), op(OpKind::Relu, 1, 2, 6)])).unwrap();
        let mut g21 = TraceGraph::new();
        g21.merge(&tr(vec![konst(2.0, 5), op(OpKind::Relu, 1, 2, 6)])).unwrap();
        g21.merge(&tr(vec![konst(1.0, 5), op(OpKind::Relu, 1, 2, 6)])).unwrap();
        assert_eq!(sig(&g12), sig(&g21));
    }

    #[test]
    fn var_types_are_part_of_the_signature() {
        let t = tr(vec![TraceItem::Op {
            def: OpDef::new(OpKind::Relu, vec![TensorType::f32(&[4])]),
            loc: loc(2),
            inputs: vec![ValueRef::Var(VarId(0))],
            outputs: vec![ValueId(2)],
        }]);
        let mut g = TraceGraph::new();
        g.merge(&t).unwrap();
        let mut small = HashMap::new();
        small.insert(VarId(0), TensorType::f32(&[4]));
        let mut big = HashMap::new();
        big.insert(VarId(0), TensorType::f32(&[8]));
        assert_ne!(graph_signature(&g, &small), graph_signature(&g, &big));
        // Unreferenced variables do not influence the signature.
        let mut extra = small.clone();
        extra.insert(VarId(7), TensorType::f32(&[64, 64]));
        assert_eq!(graph_signature(&g, &small), graph_signature(&g, &extra));
    }

    #[test]
    fn variant_order_is_significant() {
        // Variant indices are the wire format of Variant Selects: graphs
        // whose join node observed its variants in different orders are NOT
        // interchangeable, so their signatures must differ.
        let a = tr(vec![feed(1, 1), op(OpKind::Relu, 1, 2, 2), op(OpKind::Neg, 2, 3, 5)]);
        let b = tr(vec![feed(1, 1), op(OpKind::Tanh, 1, 2, 3), op(OpKind::Neg, 2, 3, 5)]);
        let mut gab = TraceGraph::new();
        gab.merge(&a).unwrap();
        gab.merge(&b).unwrap();
        let mut gba = TraceGraph::new();
        gba.merge(&b).unwrap();
        gba.merge(&a).unwrap();
        assert_ne!(sig(&gab), sig(&gba));
    }
}
