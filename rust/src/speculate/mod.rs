//! The speculation subsystem: content-addressed plan caching + adaptive
//! co-execution re-entry.
//!
//! Terra's phase machine pays the full plan pipeline — optimizer passes,
//! plan generation, segment compilation, runner spawn — on every
//! tracing→co-execution transition. After a divergence fallback the merged
//! TraceGraph is often structurally identical to one already compiled (by a
//! previous engine instance of the same program, or by the same bench loop
//! one run earlier); recompiling it is pure waste. This module makes those
//! transitions nearly free and replaces the fixed "one stable trace"
//! re-entry rule with a profile-guided policy:
//!
//! * [`signature`] — a canonical 128-bit structural hash of the TraceGraph
//!   (nodes, edges, variants, variable bindings; observation-order artifacts
//!   erased where they are semantically irrelevant),
//! * [`plancache`] — a process-global, LRU-bounded map from signature (+
//!   fusion/opt-level knobs) to the `Arc` of a fully compiled plan,
//! * [`controller`] — a divergence profiler driving K-stable re-entry with
//!   exponential backoff for thrashing programs and immediate re-entry when
//!   the plan cache already holds the current signature.
//!
//! Knobs: JSON `speculate` on [`crate::config::RunConfig`], CLI
//! `--plan-cache` / `--reentry-policy`, env `TERRA_SPECULATE`
//! (`off` = seed behaviour, `nocache`, `eager`; default fully on). See
//! `README.md` in this directory for the canonicalization and
//! cache-invalidation contract.

pub mod controller;
pub mod plancache;
pub mod signature;

pub use controller::{ReentryController, ReentryPolicy};
pub use plancache::{CachedPlan, PlanCache, PlanKey};
pub use signature::{graph_signature, GraphSig};

/// Engine-level speculation settings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpeculateConfig {
    /// Consult/populate the process-global plan cache on co-execution entry.
    pub plan_cache: bool,
    /// Phase-transition policy (see [`ReentryPolicy`]).
    pub policy: ReentryPolicy,
}

impl Default for SpeculateConfig {
    fn default() -> Self {
        SpeculateConfig { plan_cache: true, policy: ReentryPolicy::Adaptive }
    }
}

impl SpeculateConfig {
    /// Seed behaviour: no plan cache, enter on the first stable trace.
    pub fn disabled() -> Self {
        SpeculateConfig { plan_cache: false, policy: ReentryPolicy::Eager }
    }

    /// Parse a preset name (shared by the `TERRA_SPECULATE` env knob and the
    /// JSON `speculate` string form): `0`/`off` =
    /// [`SpeculateConfig::disabled`], `nocache` = adaptive policy without
    /// the cache, `eager` = cache without the adaptive policy, `1`/`on` =
    /// fully on.
    pub fn parse_preset(name: &str) -> crate::error::Result<Self> {
        match name.to_ascii_lowercase().as_str() {
            "0" | "off" => Ok(Self::disabled()),
            "nocache" => Ok(SpeculateConfig { plan_cache: false, policy: ReentryPolicy::Adaptive }),
            "eager" => Ok(SpeculateConfig { plan_cache: true, policy: ReentryPolicy::Eager }),
            "1" | "on" | "adaptive" => Ok(Self::default()),
            other => Err(crate::error::TerraError::Config(format!(
                "unknown speculate preset '{other}' (expected on | off | nocache | eager)"
            ))),
        }
    }

    /// Default settings with a `TERRA_SPECULATE` env override (see
    /// [`SpeculateConfig::parse_preset`]; an unrecognized value falls back
    /// to the default rather than erroring, matching `TERRA_OPT_LEVEL`).
    pub fn from_env() -> Self {
        match std::env::var("TERRA_SPECULATE").ok() {
            Some(v) => Self::parse_preset(&v).unwrap_or_default(),
            None => Self::default(),
        }
    }
}
