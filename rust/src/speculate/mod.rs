//! The speculation subsystem: content-addressed plan caching + adaptive
//! co-execution re-entry.
//!
//! Terra's phase machine pays the full plan pipeline — optimizer passes,
//! plan generation, segment compilation, runner spawn — on every
//! tracing→co-execution transition. After a divergence fallback the merged
//! TraceGraph is often structurally identical to one already compiled (by a
//! previous engine instance of the same program, or by the same bench loop
//! one run earlier); recompiling it is pure waste. This module makes those
//! transitions nearly free and replaces the fixed "one stable trace"
//! re-entry rule with a profile-guided policy:
//!
//! * [`signature`] — a canonical 128-bit structural hash of the TraceGraph
//!   (nodes, edges, variants, variable bindings; observation-order artifacts
//!   erased where they are semantically irrelevant),
//! * [`plancache`] — a process-global, LRU-bounded map from signature (+
//!   fusion/opt-level knobs) to the `Arc` of a fully compiled plan,
//! * [`controller`] — a divergence profiler driving K-stable re-entry with
//!   exponential backoff for thrashing programs and immediate re-entry when
//!   the plan cache already holds the current signature.
//!
//! Knobs: JSON `speculate` on [`crate::config::RunConfig`], CLI
//! `--plan-cache` / `--reentry-policy`, env `TERRA_SPECULATE`
//! (`off` = seed behaviour, `nocache`, `eager`; default fully on). See
//! `README.md` in this directory for the canonicalization and
//! cache-invalidation contract.

pub mod controller;
pub mod plancache;
pub mod signature;

pub use controller::{
    parse_site_node, split_min_count, DivergenceProfile, ReentryController, ReentryPolicy,
};
pub use plancache::{
    BuildLease, BuildRole, BuildTicket, CachedPlan, PlanCache, PlanKey, Quarantine,
    QuarantineVerdict,
};
pub use signature::{graph_signature, GraphSig};

/// Engine-level speculation settings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpeculateConfig {
    /// Consult/populate the process-global plan cache on co-execution entry.
    pub plan_cache: bool,
    /// Phase-transition policy (see [`ReentryPolicy`]).
    pub policy: ReentryPolicy,
    /// Profile-guided segment splitting: cut plan segments at historically
    /// hot divergence sites so a fallback there cancels only the downstream
    /// segments (JSON `speculate.split_hot_sites`, CLI `--split-hot-sites`,
    /// env `TERRA_SPLIT_HOT_SITES`; threshold `TERRA_SPLIT_MIN_COUNT`).
    pub split_hot_sites: bool,
}

impl Default for SpeculateConfig {
    fn default() -> Self {
        SpeculateConfig {
            plan_cache: true,
            policy: ReentryPolicy::Adaptive,
            split_hot_sites: true,
        }
    }
}

impl SpeculateConfig {
    /// Seed behaviour: no plan cache, enter on the first stable trace, no
    /// profile-guided splitting.
    pub fn disabled() -> Self {
        SpeculateConfig {
            plan_cache: false,
            policy: ReentryPolicy::Eager,
            split_hot_sites: false,
        }
    }

    /// Parse a preset name (shared by the `TERRA_SPECULATE` env knob and the
    /// JSON `speculate` string form): `0`/`off` =
    /// [`SpeculateConfig::disabled`], `nocache` = adaptive policy without
    /// the cache, `eager` = cache without the adaptive policy, `1`/`on` =
    /// fully on.
    pub fn parse_preset(name: &str) -> crate::error::Result<Self> {
        match name.to_ascii_lowercase().as_str() {
            "0" | "off" => Ok(Self::disabled()),
            "nocache" => Ok(SpeculateConfig { plan_cache: false, ..Self::default() }),
            "eager" => Ok(SpeculateConfig { policy: ReentryPolicy::Eager, ..Self::default() }),
            "nosplit" => Ok(SpeculateConfig { split_hot_sites: false, ..Self::default() }),
            "1" | "on" | "adaptive" => Ok(Self::default()),
            other => Err(crate::error::TerraError::Config(format!(
                "unknown speculate preset '{other}' (expected on | off | nocache | eager | nosplit)"
            ))),
        }
    }

    /// Default settings with env overrides: `TERRA_SPECULATE` selects a
    /// preset (see [`SpeculateConfig::parse_preset`]; an unrecognized value
    /// falls back to the default rather than erroring, matching
    /// `TERRA_OPT_LEVEL`), then `TERRA_SPLIT_HOT_SITES` overrides the
    /// segment-splitting knob on its own.
    pub fn from_env() -> Self {
        let mut cfg = match std::env::var("TERRA_SPECULATE").ok() {
            Some(v) => Self::parse_preset(&v).unwrap_or_default(),
            None => Self::default(),
        };
        if let Ok(v) = std::env::var("TERRA_SPLIT_HOT_SITES") {
            match v.to_ascii_lowercase().as_str() {
                "0" | "off" | "false" => cfg.split_hot_sites = false,
                "1" | "on" | "true" => cfg.split_hot_sites = true,
                _ => {}
            }
        }
        cfg
    }
}
