//! Gradient tape (tf.GradientTape analogue).
//!
//! The tape records forward DL ops at the *API* level and emits the backward
//! pass as ordinary session ops. That means gradients flow through whatever
//! backend is installed — eagerly executed in imperative mode, recorded in
//! tracing mode, validated in skeleton mode — so Terra's TraceGraph sees
//! forward and backward as one trace, exactly like the paper's training
//! steps.
//!
//! Determinism: entries are replayed in fixed reverse order and every emitted
//! op is wrapped in a scope derived from the forward entry index, so a
//! repeated forward path yields an identical backward op sequence (and
//! therefore a stable TraceGraph).

mod vjp;

use crate::api::{Session, TapeEntry, Tensor, Variable};
use crate::error::{Result, TerraError};
use crate::tensor::{HostTensor, TensorType};
use crate::trace::{ValueId, ValueRef, VarId};
use std::collections::HashMap;

/// An active gradient tape.
pub struct Tape {
    sess: Session,
}

impl Tape {
    /// Begin recording on `sess`.
    pub fn start(sess: &Session) -> Result<Tape> {
        sess.start_tape()?;
        Ok(Tape { sess: sess.clone() })
    }

    /// Compute `d loss / d var` for each variable, consuming the tape.
    /// Variables that do not influence `loss` get zero gradients.
    pub fn gradient(self, loss: &Tensor, vars: &[&Variable]) -> Result<Vec<Tensor>> {
        let (_, var_grads) = self.backward(loss)?;
        let sess = self.sess.clone();
        vars.iter()
            .map(|v| match var_grads.get(&v.id()) {
                Some(g) => Ok(g.clone()),
                None => zeros_tensor(&sess, v.ty()),
            })
            .collect()
    }

    /// Compute gradients w.r.t. arbitrary forward tensors.
    pub fn gradient_tensors(self, loss: &Tensor, targets: &[&Tensor]) -> Result<Vec<Tensor>> {
        let (grads, _) = self.backward(loss)?;
        let sess = self.sess.clone();
        targets
            .iter()
            .map(|t| match grads.get(&t.id()) {
                Some(g) => Ok(g.clone()),
                None => zeros_tensor(&sess, t.ty()),
            })
            .collect()
    }

    /// Run the reverse sweep; returns per-value and per-variable cotangents.
    fn backward(
        &self,
        loss: &Tensor,
    ) -> Result<(HashMap<ValueId, Tensor>, HashMap<VarId, Tensor>)> {
        let sess = &self.sess;
        let data = sess.take_tape()?;
        let _outer = sess.scope("tape");

        let mut grads: HashMap<ValueId, Tensor> = HashMap::new();
        let mut var_grads: HashMap<VarId, Tensor> = HashMap::new();

        // Seed: d loss / d loss = 1.
        let seed = ones_tensor(sess, loss.ty())?;
        grads.insert(loss.id(), seed);

        for (idx, entry) in data.entries.iter().enumerate().rev() {
            let out_grads: Vec<Option<Tensor>> =
                entry.outputs.iter().map(|id| grads.get(id).cloned()).collect();
            if out_grads.iter().all(Option::is_none) {
                continue;
            }
            let _g = sess.scope(&format!("g{idx}"));
            let in_grads = vjp::vjp(sess, entry, &out_grads)?;
            debug_assert_eq!(in_grads.len(), entry.inputs.len());
            for (i, g) in in_grads.into_iter().enumerate() {
                let Some(g) = g else { continue };
                match entry.inputs[i] {
                    ValueRef::Out(id) => accumulate(sess, &mut grads, id, g)?,
                    ValueRef::Var(v) => accumulate_var(sess, &mut var_grads, v, g)?,
                }
            }
        }
        Ok((grads, var_grads))
    }
}

fn accumulate(
    sess: &Session,
    grads: &mut HashMap<ValueId, Tensor>,
    id: ValueId,
    g: Tensor,
) -> Result<()> {
    match grads.remove(&id) {
        None => {
            grads.insert(id, g);
        }
        Some(prev) => {
            let _s = sess.scope("acc");
            grads.insert(id, prev.add(&g)?);
        }
    }
    Ok(())
}

fn accumulate_var(
    sess: &Session,
    grads: &mut HashMap<VarId, Tensor>,
    var: VarId,
    g: Tensor,
) -> Result<()> {
    match grads.remove(&var) {
        None => {
            grads.insert(var, g);
        }
        Some(prev) => {
            let _s = sess.scope("vacc");
            grads.insert(var, prev.add(&g)?);
        }
    }
    Ok(())
}

fn ones_tensor(sess: &Session, ty: &TensorType) -> Result<Tensor> {
    match ty.dtype {
        crate::tensor::DType::F32 => sess.constant(HostTensor::filled_f32(ty.shape.clone(), 1.0)),
        _ => Err(TerraError::DType("gradient seed must be f32".into())),
    }
}

fn zeros_tensor(sess: &Session, ty: &TensorType) -> Result<Tensor> {
    sess.constant(HostTensor::zeros(ty))
}

/// The entry's `i`-th input as a Tensor handle.
pub(crate) fn input_tensor(sess: &Session, e: &TapeEntry, i: usize) -> Tensor {
    sess.tensor_from_ref(e.inputs[i], e.def.in_types[i].clone())
}

/// The entry's `slot`-th output as a Tensor handle.
pub(crate) fn output_tensor(sess: &Session, e: &TapeEntry, slot: usize) -> Tensor {
    sess.tensor_from_ref(ValueRef::Out(e.outputs[slot]), e.out_types[slot].clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, EagerBackend, VarStore};
    use crate::eager::EagerExecutor;
    use crate::runtime::{ArtifactStore, Client};
    use std::sync::Arc;

    fn test_session() -> Session {
        let dir = std::env::temp_dir().join(format!("terra_tape_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        let client = Client::global().clone();
        let vars = Arc::new(VarStore::new(client.clone()));
        let exec = Arc::new(EagerExecutor::new(client, store.clone()));
        let backend: Box<dyn Backend> = Box::new(EagerBackend::new(exec, vars.clone()));
        Session::new(backend, store, vars)
    }

    fn grad_check_scalar(
        f: impl Fn(&Session, &Tensor) -> Result<Tensor>,
        x0: f32,
        expected: f32,
    ) {
        let sess = test_session();
        let v = sess.variable("x", HostTensor::scalar_f32(x0), true).unwrap();
        sess.begin_step(0).unwrap();
        let tape = Tape::start(&sess).unwrap();
        let y = f(&sess, &v.read()).unwrap();
        let grads = tape.gradient(&y, &[&v]).unwrap();
        let g = grads[0].value().unwrap().scalar_value_f32().unwrap();
        sess.end_step().unwrap();
        assert!(
            (g - expected).abs() < 1e-4 * expected.abs().max(1.0),
            "grad {g} != expected {expected}"
        );
    }

    #[test]
    fn grad_of_square() {
        grad_check_scalar(|_s, x| x.mul(x), 3.0, 6.0);
    }

    #[test]
    fn grad_of_exp() {
        grad_check_scalar(|_s, x| x.exp(), 1.2, 1.2f32.exp());
    }

    #[test]
    fn grad_of_chain() {
        // d/dx tanh(x^2) = (1 - tanh^2(x^2)) * 2x
        let x0 = 0.7f32;
        let t = (x0 * x0).tanh();
        grad_check_scalar(|_s, x| x.mul(x)?.tanh(), x0, (1.0 - t * t) * 2.0 * x0);
    }

    #[test]
    fn grad_accumulates_over_reuse() {
        // y = x*x + x => dy/dx = 2x + 1
        grad_check_scalar(|_s, x| x.mul(x)?.add(x), 2.0, 5.0);
    }

    #[test]
    fn grad_matmul() {
        let sess = test_session();
        let w = sess
            .variable("w", HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(), true)
            .unwrap();
        sess.begin_step(0).unwrap();
        let x = sess.feed(HostTensor::f32(vec![1, 2], vec![1.0, 1.0]).unwrap()).unwrap();
        let tape = Tape::start(&sess).unwrap();
        let y = x.matmul(&w.read()).unwrap(); // [1,2]
        let loss = y.reduce_sum(&[0, 1], false).unwrap();
        let grads = tape.gradient(&loss, &[&w]).unwrap();
        // d sum(x@W) / dW = x^T @ ones(1,2) = [[1,1],[1,1]]
        assert_eq!(grads[0].value().unwrap().as_f32().unwrap(), &[1.0, 1.0, 1.0, 1.0]);
        sess.end_step().unwrap();
    }

    #[test]
    fn grad_softmax_cross_entropy() {
        // loss = -log_softmax(z)[target]; dz = softmax(z) - onehot(target)
        let sess = test_session();
        let z0 = vec![0.5f32, -0.2, 1.0];
        let v = sess.variable("z", HostTensor::f32(vec![1, 3], z0.clone()).unwrap(), true).unwrap();
        sess.begin_step(0).unwrap();
        let tape = Tape::start(&sess).unwrap();
        let z = v.read();
        let lsm = z.log_softmax(1).unwrap();
        let onehot = sess
            .constant(HostTensor::f32(vec![1, 3], vec![0.0, 1.0, 0.0]).unwrap())
            .unwrap();
        let loss = lsm.mul(&onehot).unwrap().reduce_sum(&[0, 1], false).unwrap().neg().unwrap();
        let grads = tape.gradient(&loss, &[&v]).unwrap();
        let g = grads[0].value().unwrap();
        let m: f32 = z0.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = z0.iter().map(|x| (x - m).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|e| e / sum).collect();
        let expected = [probs[0], probs[1] - 1.0, probs[2]];
        for (a, b) in g.as_f32().unwrap().iter().zip(expected.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        sess.end_step().unwrap();
    }

    #[test]
    fn grad_relu_mask() {
        let sess = test_session();
        let v = sess
            .variable("x", HostTensor::f32(vec![4], vec![-1.0, 2.0, -3.0, 4.0]).unwrap(), true)
            .unwrap();
        sess.begin_step(0).unwrap();
        let tape = Tape::start(&sess).unwrap();
        let y = v.read().relu().unwrap().reduce_sum(&[0], false).unwrap();
        let grads = tape.gradient(&y, &[&v]).unwrap();
        assert_eq!(grads[0].value().unwrap().as_f32().unwrap(), &[0.0, 1.0, 0.0, 1.0]);
        sess.end_step().unwrap();
    }

    #[test]
    fn grad_broadcast_unbroadcasts() {
        // y = sum(x + b) with x [2,3], b [3] => db = [2,2,2]
        let sess = test_session();
        let b = sess.variable("b", HostTensor::f32(vec![3], vec![0.0; 3]).unwrap(), true).unwrap();
        sess.begin_step(0).unwrap();
        let x = sess.feed(HostTensor::f32(vec![2, 3], vec![1.0; 6]).unwrap()).unwrap();
        let tape = Tape::start(&sess).unwrap();
        let y = x.add(&b.read()).unwrap().reduce_sum(&[0, 1], false).unwrap();
        let grads = tape.gradient(&y, &[&b]).unwrap();
        assert_eq!(grads[0].value().unwrap().as_f32().unwrap(), &[2.0, 2.0, 2.0]);
        sess.end_step().unwrap();
    }

    #[test]
    fn grad_take_embedding() {
        // W [3,2]; take rows [0, 0, 2]; loss = sum => dW rows: [2,2],[0,0],[1,1]
        let sess = test_session();
        let w = sess
            .variable("emb", HostTensor::f32(vec![3, 2], vec![0.0; 6]).unwrap(), true)
            .unwrap();
        sess.begin_step(0).unwrap();
        let idx = sess.feed(HostTensor::i32(vec![3], vec![0, 0, 2]).unwrap()).unwrap();
        let tape = Tape::start(&sess).unwrap();
        let y = w.read().take(&idx, 0).unwrap().reduce_sum(&[0, 1], false).unwrap();
        let grads = tape.gradient(&y, &[&w]).unwrap();
        assert_eq!(
            grads[0].value().unwrap().as_f32().unwrap(),
            &[2.0, 2.0, 0.0, 0.0, 1.0, 1.0]
        );
        sess.end_step().unwrap();
    }

    #[test]
    fn unused_variable_gets_zeros() {
        let sess = test_session();
        let used = sess.variable("u", HostTensor::scalar_f32(1.0), true).unwrap();
        let unused = sess.variable("n", HostTensor::f32(vec![2], vec![0.0; 2]).unwrap(), true).unwrap();
        sess.begin_step(0).unwrap();
        let tape = Tape::start(&sess).unwrap();
        let y = used.read().mul_scalar(3.0).unwrap();
        let grads = tape.gradient(&y, &[&used, &unused]).unwrap();
        assert_eq!(grads[0].value().unwrap().scalar_value_f32().unwrap(), 3.0);
        assert_eq!(grads[1].value().unwrap().as_f32().unwrap(), &[0.0, 0.0]);
        sess.end_step().unwrap();
    }
}
