//! Vector-Jacobian products for each `OpKind`.
//!
//! Each rule maps an output cotangent to input cotangents, emitting ordinary
//! session ops. Rules follow the standard definitions (jax/lax conventions);
//! reductions over broadcast dimensions are handled by [`unbroadcast`].

use crate::api::{Session, TapeEntry, Tensor};
use crate::error::{Result, TerraError};
use crate::ops::OpKind;
use crate::tape::{input_tensor, output_tensor};
use crate::tensor::{DType, Shape};

/// Sum `g` down to `target` shape (reverse of numpy broadcasting).
fn unbroadcast(g: &Tensor, target: &Shape) -> Result<Tensor> {
    let gs = g.ty().shape.clone();
    if &gs == target {
        return Ok(g.clone());
    }
    let extra = gs.rank() - target.rank();
    let mut axes: Vec<usize> = (0..extra).collect();
    for (i, &d) in target.dims().iter().enumerate() {
        if d == 1 && gs.dims()[i + extra] != 1 {
            axes.push(i + extra);
        }
    }
    let reduced = if axes.is_empty() { g.clone() } else { g.reduce_sum(&axes, false)? };
    if reduced.ty().shape == *target {
        Ok(reduced)
    } else {
        reduced.reshape(target.dims())
    }
}

/// Transpose the last two axes (batched matrix transpose).
fn mt(t: &Tensor) -> Result<Tensor> {
    let r = t.ty().shape.rank();
    let mut perm: Vec<usize> = (0..r).collect();
    perm.swap(r - 2, r - 1);
    t.transpose(&perm)
}

/// Compute input cotangents for `entry` given output cotangents.
/// Returns one `Option<Tensor>` per input (None = no gradient flows).
pub(crate) fn vjp(
    sess: &Session,
    e: &TapeEntry,
    out_grads: &[Option<Tensor>],
) -> Result<Vec<Option<Tensor>>> {
    let g = out_grads.first().and_then(|o| o.clone());
    let nin = e.inputs.len();
    let none = |n: usize| -> Vec<Option<Tensor>> { vec![None; n] };
    let kind = &e.def.kind;

    // Ops with no gradient (integer outputs, RNG, index manipulation).
    match kind {
        OpKind::Greater
        | OpKind::GreaterEqual
        | OpKind::Less
        | OpKind::LessEqual
        | OpKind::Equal
        | OpKind::NotEqual
        | OpKind::Sign
        | OpKind::OneHot { .. }
        | OpKind::RngUniform { .. }
        | OpKind::RngNormal { .. }
        | OpKind::Convert { .. } => return Ok(none(nin)),
        _ => {}
    }

    let Some(g) = g else { return Ok(none(nin)) };
    let in_shape = |i: usize| e.def.in_types[i].shape.clone();

    Ok(match kind {
        OpKind::Add => vec![
            Some(unbroadcast(&g, &in_shape(0))?),
            Some(unbroadcast(&g, &in_shape(1))?),
        ],
        OpKind::Sub => vec![
            Some(unbroadcast(&g, &in_shape(0))?),
            Some(unbroadcast(&g.neg()?, &in_shape(1))?),
        ],
        OpKind::Mul => {
            let a = input_tensor(sess, e, 0);
            let b = input_tensor(sess, e, 1);
            vec![
                Some(unbroadcast(&g.mul(&b)?, &in_shape(0))?),
                Some(unbroadcast(&g.mul(&a)?, &in_shape(1))?),
            ]
        }
        OpKind::Div => {
            let a = input_tensor(sess, e, 0);
            let b = input_tensor(sess, e, 1);
            let ga = g.div(&b)?;
            let gb = g.mul(&a)?.neg()?.div(&b.mul(&b)?)?;
            vec![
                Some(unbroadcast(&ga, &in_shape(0))?),
                Some(unbroadcast(&gb, &in_shape(1))?),
            ]
        }
        OpKind::Maximum | OpKind::Minimum => {
            let a = input_tensor(sess, e, 0);
            let b = input_tensor(sess, e, 1);
            let mask = if matches!(kind, OpKind::Maximum) {
                a.greater_equal(&b)?.convert(DType::F32)?
            } else {
                a.less_equal(&b)?.convert(DType::F32)?
            };
            let one_minus = mask.neg()?.add_scalar(1.0)?;
            vec![
                Some(unbroadcast(&g.mul(&mask)?, &in_shape(0))?),
                Some(unbroadcast(&g.mul(&one_minus)?, &in_shape(1))?),
            ]
        }
        OpKind::Pow => {
            let a = input_tensor(sess, e, 0);
            let b = input_tensor(sess, e, 1);
            let y = output_tensor(sess, e, 0);
            let ga = g.mul(&b)?.mul(&a.pow(&b.sub_scalar(1.0)?)?)?;
            let gb = g.mul(&a.log()?)?.mul(&y)?;
            vec![
                Some(unbroadcast(&ga, &in_shape(0))?),
                Some(unbroadcast(&gb, &in_shape(1))?),
            ]
        }
        OpKind::Neg => vec![Some(g.neg()?)],
        OpKind::Exp => {
            let y = output_tensor(sess, e, 0);
            vec![Some(g.mul(&y)?)]
        }
        OpKind::Log => {
            let x = input_tensor(sess, e, 0);
            vec![Some(g.div(&x)?)]
        }
        OpKind::Sqrt => {
            let y = output_tensor(sess, e, 0);
            vec![Some(g.mul_scalar(0.5)?.div(&y)?)]
        }
        OpKind::Rsqrt => {
            let y = output_tensor(sess, e, 0);
            vec![Some(g.mul_scalar(-0.5)?.mul(&y.mul(&y)?.mul(&y)?)?)]
        }
        OpKind::Tanh => {
            let y = output_tensor(sess, e, 0);
            vec![Some(g.mul(&y.mul(&y)?.neg()?.add_scalar(1.0)?)?)]
        }
        OpKind::Sigmoid => {
            let y = output_tensor(sess, e, 0);
            vec![Some(g.mul(&y)?.mul(&y.neg()?.add_scalar(1.0)?)?)]
        }
        OpKind::Relu => {
            let x = input_tensor(sess, e, 0);
            let mask = x.greater_scalar(0.0)?.convert(DType::F32)?;
            vec![Some(g.mul(&mask)?)]
        }
        OpKind::Abs => {
            let x = input_tensor(sess, e, 0);
            vec![Some(g.mul(&x.sign()?)?)]
        }
        OpKind::Select => {
            let cond = input_tensor(sess, e, 0);
            let mask = cond.convert(DType::F32)?;
            let inv = mask.neg()?.add_scalar(1.0)?;
            vec![
                None,
                Some(unbroadcast(&g.mul(&mask)?, &in_shape(1))?),
                Some(unbroadcast(&g.mul(&inv)?, &in_shape(2))?),
            ]
        }
        OpKind::MatMul => {
            let a = input_tensor(sess, e, 0);
            let b = input_tensor(sess, e, 1);
            let ga = g.matmul(&mt(&b)?)?;
            let gb = mt(&a)?.matmul(&g)?;
            vec![
                Some(unbroadcast(&ga, &in_shape(0))?),
                Some(unbroadcast(&gb, &in_shape(1))?),
            ]
        }
        OpKind::Transpose { perm } => {
            let mut inv = vec![0usize; perm.len()];
            for (i, &p) in perm.iter().enumerate() {
                inv[p] = i;
            }
            vec![Some(g.transpose(&inv)?)]
        }
        OpKind::Reshape { .. } => vec![Some(g.reshape(in_shape(0).dims())?)],
        OpKind::Broadcast { .. } => vec![Some(unbroadcast(&g, &in_shape(0))?)],
        OpKind::Concat { axis } => {
            let mut out = Vec::with_capacity(nin);
            let mut offset = 0usize;
            for i in 0..nin {
                let sh = in_shape(i);
                let mut starts = vec![0usize; sh.rank()];
                starts[*axis] = offset;
                out.push(Some(g.slice(&starts, sh.dims())?));
                offset += sh.dims()[*axis];
            }
            out
        }
        OpKind::Slice { starts, sizes } => {
            let sh = in_shape(0);
            let low = starts.clone();
            let high: Vec<usize> = sh
                .dims()
                .iter()
                .zip(starts.iter().zip(sizes.iter()))
                .map(|(&d, (&s, &z))| d - s - z)
                .collect();
            vec![Some(g.pad(&low, &high)?)]
        }
        OpKind::Pad { low, .. } => {
            let sh = in_shape(0);
            vec![Some(g.slice(low, sh.dims())?)]
        }
        OpKind::ReduceSum { axes, keep_dims } => {
            let sh = in_shape(0);
            let gk = if *keep_dims { g.clone() } else { g.reshape(keep_shape(&sh, axes).dims())? };
            vec![Some(gk.broadcast_to(sh.dims())?)]
        }
        OpKind::ReduceMean { axes, keep_dims } => {
            let sh = in_shape(0);
            let count: usize = axes.iter().map(|&a| sh.dims()[a]).product();
            let gk = if *keep_dims { g.clone() } else { g.reshape(keep_shape(&sh, axes).dims())? };
            vec![Some(gk.broadcast_to(sh.dims())?.div_scalar(count as f32)?)]
        }
        OpKind::ReduceMax { axes, keep_dims } => {
            let x = input_tensor(sess, e, 0);
            let sh = in_shape(0);
            let y = output_tensor(sess, e, 0);
            let yk = if *keep_dims { y } else { y.reshape(keep_shape(&sh, axes).dims())? };
            let mask = x.equal(&yk.broadcast_to(sh.dims())?)?.convert(DType::F32)?;
            let gk = if *keep_dims { g.clone() } else { g.reshape(keep_shape(&sh, axes).dims())? };
            vec![Some(gk.broadcast_to(sh.dims())?.mul(&mask)?)]
        }
        OpKind::Softmax { axis } => {
            let y = output_tensor(sess, e, 0);
            let dot = g.mul(&y)?.reduce_sum(&[*axis], true)?;
            vec![Some(y.mul(&g.sub(&dot)?)?)]
        }
        OpKind::LogSoftmax { axis } => {
            let y = output_tensor(sess, e, 0);
            let sum_g = g.reduce_sum(&[*axis], true)?;
            vec![Some(g.sub(&y.exp()?.mul(&sum_g)?)?)]
        }
        OpKind::Take { axis } => {
            // Embedding-style gradient: supported for rank-2 data, axis 0.
            let sh = in_shape(0);
            if *axis != 0 || sh.rank() != 2 {
                return Err(TerraError::runtime(
                    "take gradient only supported for rank-2 data along axis 0",
                ));
            }
            let (v, d) = (sh.dims()[0], sh.dims()[1]);
            let idx = input_tensor(sess, e, 1);
            let n = idx.ty().shape.num_elements();
            let onehot = idx.reshape(&[n])?.one_hot(v)?; // [n, V]
            let gm = g.reshape(&[n, d])?; // [n, D]
            let gw = onehot.transpose(&[1, 0])?.matmul(&gm)?; // [V, D]
            vec![Some(gw), None]
        }
        OpKind::ArtifactCall { name, .. } => {
            let meta = sess.artifacts().meta(name)?;
            if meta.nondiff {
                return Ok(none(nin)); // declared stop-gradient (mask/RNG-like)
            }
            let Some(vjp_name) = meta.vjp.clone() else {
                return Err(TerraError::Artifact(format!(
                    "artifact '{name}' has no registered vjp; cannot differentiate"
                )));
            };
            // Convention: bwd artifact takes (fwd inputs..., out cotangents...)
            // and returns one cotangent per differentiable fwd input (zeros
            // for integer inputs, which we drop to None).
            let mut args: Vec<Tensor> = (0..nin).map(|i| input_tensor(sess, e, i)).collect();
            for (slot, og) in out_grads.iter().enumerate() {
                match og {
                    Some(t) => args.push(t.clone()),
                    None => {
                        // Dense zero cotangent for unused outputs.
                        let ty = &e.out_types[slot];
                        args.push(sess.constant(crate::tensor::HostTensor::zeros(ty))?);
                    }
                }
            }
            let arg_refs: Vec<&Tensor> = args.iter().collect();
            let outs = sess.artifact_call(&vjp_name, &arg_refs)?;
            if outs.len() != nin {
                return Err(TerraError::Artifact(format!(
                    "vjp artifact '{vjp_name}' returned {} grads for {nin} inputs",
                    outs.len()
                )));
            }
            outs.into_iter()
                .enumerate()
                .map(|(i, t)| if e.def.in_types[i].dtype == DType::F32 { Some(t) } else { None })
                .collect()
        }
        other => {
            return Err(TerraError::runtime(format!(
                "no vjp rule for op {other}"
            )))
        }
    })
}

/// The input shape with reduced axes set to 1 (keep-dims form).
fn keep_shape(sh: &Shape, axes: &[usize]) -> Shape {
    let mut dims = sh.dims().to_vec();
    for &a in axes {
        dims[a] = 1;
    }
    Shape(dims)
}
