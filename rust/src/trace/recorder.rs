//! Incremental trace recorder used by the tracing-phase backend.

use crate::error::Result;
use crate::trace::{Trace, TraceItem};

/// Collects the current iteration's items and finalizes them into a `Trace`.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    items: Vec<TraceItem>,
    step: u64,
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn begin_step(&mut self, step: u64) {
        self.items.clear();
        self.step = step;
    }

    pub fn record(&mut self, item: TraceItem) {
        self.items.push(item);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Finish the iteration, producing a dataflow-resolved `Trace`.
    pub fn finish(&mut self) -> Result<Trace> {
        Trace::resolve(std::mem::take(&mut self.items), self.step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{HostTensor, TensorType};
    use crate::trace::{FeedKind, Location, ValueId};

    #[test]
    fn records_and_finishes() {
        let mut r = TraceRecorder::new();
        r.begin_step(3);
        r.record(TraceItem::Feed {
            id: ValueId(1),
            ty: TensorType::f32(&[2]),
            loc: Location::synthetic("t"),
            kind: FeedKind::Data,
        });
        r.record(TraceItem::Const {
            id: ValueId(2),
            value: HostTensor::scalar_f32(1.0),
            loc: Location::synthetic("c"),
        });
        assert_eq!(r.len(), 2);
        let t = r.finish().unwrap();
        assert_eq!(t.step, 3);
        assert_eq!(t.len(), 2);
        assert!(r.is_empty());
    }
}
