//! Trace representation: what the GraphGenerator collects during the tracing
//! phase (paper §4.1) and what the PythonRunner walks during co-execution.
//!
//! A `Trace` is one iteration's linear chain of DL-side events. Besides DL
//! ops it records the communication-relevant host interactions: feeds (data
//! or captured host state), inline constants, variable assignments and
//! materializations (fetch points). Every item carries the *program location*
//! (`file:line:col` + the session scope stack), which is the third leg of the
//! paper's node-equality criteria (Appendix A).

mod ids;
mod items;
mod loops;
mod recorder;

pub use ids::{fnv1a, Location, ScopeStack, StateId, ValueId, VarId, FNV_OFFSET, FNV_PRIME};
pub use items::{const_hash, FeedKind, ItemKey, ItemPos, ResolvedSrc, Trace, TraceItem, ValueRef};
pub use loops::detect_tandem_repeats;
pub use recorder::TraceRecorder;
