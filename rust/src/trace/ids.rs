//! Identifiers and program locations.

/// Unique id of a tensor value within one engine run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub u64);

/// Unique id of a variable (persistent, trainable or not).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarId(pub u32);

/// Unique id of a mutable host-state cell (the "Python object" analogue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StateId(pub u32);

/// FNV-1a 64-bit offset basis — the project's stable-hash parameters, shared
/// with the speculation graph signature (`speculate::signature`).
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a 64-bit hash (dependency-free stable hashing for locations, consts).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// A program location: the call site of the op in the user program
/// (captured via `#[track_caller]`) plus the session's scope stack.
///
/// The scope stack plays the role of TF name scopes: library code (layers,
/// gradient tape) pushes scopes so that ops emitted from shared library lines
/// still get distinct, *deterministic* locations across iterations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Location {
    pub file: &'static str,
    pub line: u32,
    pub col: u32,
    /// Hash of the scope stack active when the op was issued.
    pub scope: u64,
}

impl Location {
    pub fn caller(caller: &'static std::panic::Location<'static>, scope: u64) -> Self {
        Location { file: caller.file(), line: caller.line(), col: caller.column(), scope }
    }

    /// A synthetic location for engine-internal events.
    pub fn synthetic(tag: &'static str) -> Self {
        Location { file: tag, line: 0, col: 0, scope: 0 }
    }

    pub fn hash64(&self) -> u64 {
        let mut h = fnv1a(self.file.as_bytes());
        h ^= (self.line as u64).wrapping_mul(0x9e3779b97f4a7c15);
        h ^= (self.col as u64).wrapping_mul(0xbf58476d1ce4e5b9);
        h ^= self.scope;
        h
    }
}

impl std::fmt::Display for Location {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{}@{:08x}", self.file, self.line, self.col, self.scope & 0xffff_ffff)
    }
}

/// The scope stack itself, owned by the session.
#[derive(Debug, Default, Clone)]
pub struct ScopeStack {
    names: Vec<String>,
    hash: u64,
}

impl ScopeStack {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str) {
        self.names.push(name.to_string());
        self.rehash();
    }

    pub fn pop(&mut self) {
        self.names.pop();
        self.rehash();
    }

    fn rehash(&mut self) {
        let mut h = 0u64;
        for n in &self.names {
            h = h.wrapping_mul(0x100000001b3) ^ fnv1a(n.as_bytes());
        }
        self.hash = h;
    }

    pub fn hash(&self) -> u64 {
        self.hash
    }

    pub fn depth(&self) -> usize {
        self.names.len()
    }

    pub fn path(&self) -> String {
        self.names.join("/")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_stack_hash_changes_and_restores() {
        let mut s = ScopeStack::new();
        let h0 = s.hash();
        s.push("layer1");
        let h1 = s.hash();
        assert_ne!(h0, h1);
        s.push("grad#3");
        let h2 = s.hash();
        assert_ne!(h1, h2);
        s.pop();
        assert_eq!(s.hash(), h1);
        s.pop();
        assert_eq!(s.hash(), h0);
        assert_eq!(s.depth(), 0);
    }

    #[test]
    fn scope_order_matters() {
        let mut a = ScopeStack::new();
        a.push("x");
        a.push("y");
        let mut b = ScopeStack::new();
        b.push("y");
        b.push("x");
        assert_ne!(a.hash(), b.hash());
    }

    #[test]
    fn location_hash_distinguishes_lines() {
        let a = Location { file: "f.rs", line: 1, col: 1, scope: 0 };
        let b = Location { file: "f.rs", line: 2, col: 1, scope: 0 };
        assert_ne!(a.hash64(), b.hash64());
    }
}
