//! Tandem-repeat (loop) detection over trace location sequences.
//!
//! The paper's GraphGenerator groups nodes executed in the same program loop
//! into a Loop node (§4.2). In this reproduction loops are *unrolled* in the
//! TraceGraph (the paper itself unrolls loops with constant trip counts as an
//! optimization; varying trip counts become TraceGraph branches and are
//! handled by the Switch-Case machinery). This module still detects tandem
//! repeats so the trace dump and the graph statistics can report loop
//! structure, and so a future While-lowering has the analysis it needs.

use crate::trace::{fnv1a, Trace};

/// A detected repeat: `body_len` items starting at `start`, repeated `trips`
/// times back-to-back (by program location).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TandemRepeat {
    pub start: usize,
    pub body_len: usize,
    pub trips: usize,
}

/// Detect maximal, non-overlapping tandem repeats in the trace's location
/// sequence, greedily from the left, preferring the smallest period at each
/// position. O(n · p_max) with rolling-hash range comparison.
pub fn detect_tandem_repeats(trace: &Trace, max_period: usize) -> Vec<TandemRepeat> {
    let locs: Vec<u64> = trace.items.iter().map(|it| it.loc().hash64()).collect();
    let n = locs.len();
    // Prefix hashes for O(1) range equality (probabilistic, 64-bit).
    let mut prefix = vec![0u64; n + 1];
    for i in 0..n {
        let mut bytes = [0u8; 16];
        bytes[..8].copy_from_slice(&prefix[i].to_le_bytes());
        bytes[8..].copy_from_slice(&locs[i].to_le_bytes());
        prefix[i + 1] = fnv1a(&bytes);
    }
    // Rolling range hash is awkward with chained fnv; use direct comparison
    // with an early-exit hash of the first element instead. For the trace
    // sizes involved (1e3-1e4 items) this stays fast because mismatches are
    // caught on the first element nearly always.
    let range_eq = |a: usize, b: usize, len: usize| -> bool {
        if a + len > n || b + len > n {
            return false;
        }
        locs[a..a + len] == locs[b..b + len]
    };

    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        let mut found: Option<TandemRepeat> = None;
        let pmax = max_period.min((n - i) / 2);
        for p in 1..=pmax {
            if range_eq(i, i + p, p) {
                // Count how many times the body repeats.
                let mut trips = 2;
                while range_eq(i, i + trips * p, p) {
                    trips += 1;
                }
                found = Some(TandemRepeat { start: i, body_len: p, trips });
                break; // smallest period wins
            }
        }
        match found {
            Some(r) => {
                i = r.start + r.body_len * r.trips;
                out.push(r);
            }
            None => i += 1,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{OpDef, OpKind};
    use crate::tensor::TensorType;
    use crate::trace::{Location, TraceItem, ValueId, ValueRef, VarId};

    fn op_at(line: u32, out: u64) -> TraceItem {
        TraceItem::Op {
            def: OpDef::new(OpKind::Relu, vec![TensorType::f32(&[2])]),
            loc: Location { file: "t.rs", line, col: 1, scope: 0 },
            inputs: vec![ValueRef::Var(VarId(0))],
            outputs: vec![ValueId(out)],
        }
    }

    fn trace_of(lines: &[u32]) -> Trace {
        let items: Vec<TraceItem> =
            lines.iter().enumerate().map(|(i, &l)| op_at(l, i as u64 + 1)).collect();
        Trace::resolve(items, 0).unwrap()
    }

    #[test]
    fn detects_simple_loop() {
        // lines: 1, [2,3] x 4, 9
        let t = trace_of(&[1, 2, 3, 2, 3, 2, 3, 2, 3, 9]);
        let reps = detect_tandem_repeats(&t, 16);
        assert_eq!(reps, vec![TandemRepeat { start: 1, body_len: 2, trips: 4 }]);
    }

    #[test]
    fn detects_unit_period() {
        let t = trace_of(&[5, 5, 5, 7]);
        let reps = detect_tandem_repeats(&t, 16);
        assert_eq!(reps, vec![TandemRepeat { start: 0, body_len: 1, trips: 3 }]);
    }

    #[test]
    fn no_repeats() {
        let t = trace_of(&[1, 2, 3, 4]);
        assert!(detect_tandem_repeats(&t, 16).is_empty());
    }

    #[test]
    fn nested_outer_detected_first() {
        // [a b b] x 2 → smallest period at pos 1 is the inner b,b
        let t = trace_of(&[1, 2, 2, 1, 2, 2]);
        let reps = detect_tandem_repeats(&t, 16);
        // Greedy smallest-period finds the whole tandem [1,2,2][1,2,2] at 0
        // only if period 3 checked before finding smaller ones; period 1 at
        // index 1 matches first under left-greedy smallest-period policy.
        assert!(!reps.is_empty());
    }
}
