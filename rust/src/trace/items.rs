//! Trace items, value references and equality keys.

use crate::error::{Result, TerraError};
use crate::ops::OpDef;
use crate::tensor::{HostTensor, TensorType};
use crate::trace::ids::{fnv1a, Location, StateId, ValueId, VarId};
use std::collections::HashMap;

/// How an op input is referenced at issue time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ValueRef {
    /// Output of a previous item in this iteration.
    Out(ValueId),
    /// Current value of a persistent variable.
    Var(VarId),
}

/// Classification of feed points (paper's Input-Feeding operations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FeedKind {
    /// Per-step program input (training batch). The AutoGraph baseline
    /// supports these (they are function arguments).
    Data,
    /// A read of mutable host state (the "Python object" analogue). The
    /// AutoGraph baseline *bakes* the captured value — the Fig. 1c bug.
    Captured(StateId),
}

/// One event of an iteration's trace.
#[derive(Debug, Clone)]
pub enum TraceItem {
    /// A DL operation (decoupled from the imperative execution).
    Op { def: OpDef, loc: Location, inputs: Vec<ValueRef>, outputs: Vec<ValueId> },
    /// A host value entering the DL side.
    Feed { id: ValueId, ty: TensorType, loc: Location, kind: FeedKind },
    /// An inline constant (may be generalized to a feed on value mismatch).
    Const { id: ValueId, value: HostTensor, loc: Location },
    /// A variable update.
    Assign { var: VarId, src: ValueRef, loc: Location },
    /// A materialization point (paper's Output-Fetching operation).
    Fetch { src: ValueRef, loc: Location },
}

impl TraceItem {
    pub fn loc(&self) -> Location {
        match self {
            TraceItem::Op { loc, .. }
            | TraceItem::Feed { loc, .. }
            | TraceItem::Const { loc, .. }
            | TraceItem::Assign { loc, .. }
            | TraceItem::Fetch { loc, .. } => *loc,
        }
    }

    pub fn outputs(&self) -> &[ValueId] {
        match self {
            TraceItem::Op { outputs, .. } => outputs,
            TraceItem::Feed { id, .. } | TraceItem::Const { id, .. } => std::slice::from_ref(id),
            _ => &[],
        }
    }

    pub fn inputs(&self) -> Vec<ValueRef> {
        match self {
            TraceItem::Op { inputs, .. } => inputs.clone(),
            TraceItem::Assign { src, .. } | TraceItem::Fetch { src, .. } => vec![*src],
            _ => vec![],
        }
    }

    /// The node-equality key (paper Appendix A: operation type, attributes,
    /// program location). Input *sources* are compared structurally during
    /// merging, not via the key.
    pub fn key(&self) -> ItemKey {
        match self {
            TraceItem::Op { def, loc, .. } => ItemKey::Op { def: def.clone(), loc: *loc },
            TraceItem::Feed { ty, loc, kind, .. } => {
                ItemKey::Feed { ty: ty.clone(), kind: *kind, loc: *loc }
            }
            TraceItem::Const { value, loc, .. } => ItemKey::Const {
                ty: value.ty(),
                loc: *loc,
                value_hash: const_hash(value),
            },
            TraceItem::Assign { var, loc, .. } => ItemKey::Assign { var: *var, loc: *loc },
            TraceItem::Fetch { loc, .. } => ItemKey::Fetch { loc: *loc },
        }
    }
}

/// Stable content hash of a constant's bytes (used for Const equality).
pub fn const_hash(t: &HostTensor) -> u64 {
    match t {
        HostTensor::F32 { data, .. } => {
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for v in data {
                bytes.extend_from_slice(&v.to_bits().to_le_bytes());
            }
            fnv1a(&bytes)
        }
        HostTensor::I32 { data, .. } => {
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for v in data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            fnv1a(&bytes)
        }
    }
}

/// Equality key of a trace item / TraceGraph node.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ItemKey {
    Op { def: OpDef, loc: Location },
    Feed { ty: TensorType, kind: FeedKind, loc: Location },
    Const { ty: TensorType, loc: Location, value_hash: u64 },
    Assign { var: VarId, loc: Location },
    Fetch { loc: Location },
}

impl ItemKey {
    pub fn loc(&self) -> Location {
        match self {
            ItemKey::Op { loc, .. }
            | ItemKey::Feed { loc, .. }
            | ItemKey::Const { loc, .. }
            | ItemKey::Assign { loc, .. }
            | ItemKey::Fetch { loc } => *loc,
        }
    }

    /// Key equality *up to constant value*: used when a Const node has been
    /// generalized into a feed after observing different values at the same
    /// location.
    pub fn matches_generalized(&self, other: &ItemKey) -> bool {
        match (self, other) {
            (
                ItemKey::Const { ty: ta, loc: la, .. },
                ItemKey::Const { ty: tb, loc: lb, .. },
            ) => ta == tb && la == lb,
            (a, b) => a == b,
        }
    }

    pub fn short(&self) -> String {
        match self {
            ItemKey::Op { def, .. } => format!("{}", def.kind),
            ItemKey::Feed { ty, kind, .. } => match kind {
                FeedKind::Data => format!("feed:{ty}"),
                FeedKind::Captured(s) => format!("feed[state{}]:{ty}", s.0),
            },
            ItemKey::Const { ty, .. } => format!("const:{ty}"),
            ItemKey::Assign { var, .. } => format!("assign:v{}", var.0),
            ItemKey::Fetch { .. } => "fetch".to_string(),
        }
    }
}

/// Position of a produced value inside a trace: (item index, output slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ItemPos {
    pub item: usize,
    pub slot: usize,
}

/// A structurally resolved input source.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResolvedSrc {
    /// Output `slot` of item `item` earlier in the same trace.
    Item(ItemPos),
    /// Current value of a variable (as of the last preceding assign).
    Var(VarId),
}

/// One iteration's trace with resolved dataflow.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub items: Vec<TraceItem>,
    /// Per item: resolved input sources (parallel to `TraceItem::inputs()`).
    pub resolved: Vec<Vec<ResolvedSrc>>,
    /// Iteration index this trace came from (diagnostics).
    pub step: u64,
}

impl Trace {
    /// Build a trace from raw items, resolving `ValueRef::Out` ids to item
    /// positions. Fails if an id is referenced but never produced (values
    /// must not leak across iterations except through variables).
    pub fn resolve(items: Vec<TraceItem>, step: u64) -> Result<Trace> {
        let mut producers: HashMap<ValueId, ItemPos> = HashMap::new();
        let mut resolved = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            let mut srcs = Vec::new();
            for r in item.inputs() {
                match r {
                    ValueRef::Var(v) => srcs.push(ResolvedSrc::Var(v)),
                    ValueRef::Out(id) => {
                        let pos = producers.get(&id).copied().ok_or_else(|| {
                            TerraError::Trace(format!(
                                "value {id:?} used at item {i} ({}) was not produced in this \
                                 iteration; cross-iteration tensors must go through variables",
                                item.loc()
                            ))
                        })?;
                        srcs.push(ResolvedSrc::Item(pos));
                    }
                }
            }
            resolved.push(srcs);
            for (slot, id) in item.outputs().iter().enumerate() {
                producers.insert(*id, ItemPos { item: i, slot });
            }
        }
        Ok(Trace { items, resolved, step })
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Render a compact textual form (for `terra trace-dump` and tests).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for (i, item) in self.items.iter().enumerate() {
            s.push_str(&format!("{i:4}  {}  @{}\n", item.key().short(), item.loc()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::OpKind;
    use crate::tensor::TensorType;

    fn loc(line: u32) -> Location {
        Location { file: "test.rs", line, col: 1, scope: 0 }
    }

    #[test]
    fn resolve_links_producers() {
        let items = vec![
            TraceItem::Feed { id: ValueId(1), ty: TensorType::f32(&[2]), loc: loc(1), kind: FeedKind::Data },
            TraceItem::Op {
                def: OpDef::new(OpKind::Relu, vec![TensorType::f32(&[2])]),
                loc: loc(2),
                inputs: vec![ValueRef::Out(ValueId(1))],
                outputs: vec![ValueId(2)],
            },
            TraceItem::Fetch { src: ValueRef::Out(ValueId(2)), loc: loc(3) },
        ];
        let t = Trace::resolve(items, 0).unwrap();
        assert_eq!(t.resolved[1], vec![ResolvedSrc::Item(ItemPos { item: 0, slot: 0 })]);
        assert_eq!(t.resolved[2], vec![ResolvedSrc::Item(ItemPos { item: 1, slot: 0 })]);
    }

    #[test]
    fn resolve_rejects_unknown_ids() {
        let items = vec![TraceItem::Fetch { src: ValueRef::Out(ValueId(99)), loc: loc(1) }];
        assert!(Trace::resolve(items, 0).is_err());
    }

    #[test]
    fn const_keys_hash_values() {
        let a = TraceItem::Const { id: ValueId(1), value: HostTensor::scalar_f32(1.0), loc: loc(1) };
        let b = TraceItem::Const { id: ValueId(2), value: HostTensor::scalar_f32(2.0), loc: loc(1) };
        assert_ne!(a.key(), b.key());
        assert!(a.key().matches_generalized(&b.key()));
    }

    #[test]
    fn op_keys_compare_kind_types_loc() {
        let mk = |line: u32, n: usize| TraceItem::Op {
            def: OpDef::new(OpKind::Relu, vec![TensorType::f32(&[n])]),
            loc: loc(line),
            inputs: vec![ValueRef::Var(VarId(0))],
            outputs: vec![ValueId(1)],
        };
        assert_eq!(mk(1, 2).key(), mk(1, 2).key());
        assert_ne!(mk(1, 2).key(), mk(2, 2).key()); // location differs
        assert_ne!(mk(1, 2).key(), mk(1, 3).key()); // input type differs
    }
}
