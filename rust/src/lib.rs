//! # Terra: imperative-symbolic co-execution
//!
//! A full reproduction of *"Terra: Imperative-Symbolic Co-Execution of
//! Imperative Deep Learning Programs"* (NeurIPS 2021) on a Rust + JAX/Pallas
//! + XLA/PJRT stack. See `DESIGN.md` for the architecture and the
//! paper-to-testbed substitution record.
//!
//! The crate is organized bottom-up:
//!
//! * substrates: [`tensor`], [`ops`], [`runtime`], [`eager`], [`config`],
//!   [`data`], [`nn`], [`tape`]
//! * the paper's system: [`api`] (imperative program surface), [`trace`],
//!   [`tracegraph`], [`opt`] (graph-optimization passes between trace
//!   merging and plan generation), [`graphgen`], [`symbolic`], [`speculate`]
//!   (plan cache + adaptive re-entry), [`runner`]
//! * evaluation: [`baselines`], [`programs`], [`metrics`], [`bench`]
//! * observability: [`obs`] (flight-recorder tracing, Chrome-trace export,
//!   latency histograms, fault dumps)
//! * serving: [`serve`] (multi-tenant runtime/session split: shared plan
//!   cache with cross-session build coalescing, pooled workers behind a
//!   parallelism budget, FIFO admission)

pub mod api;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod data;
pub mod eager;
pub mod error;
pub mod faults;
pub mod graphgen;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod ops;
pub mod opt;
pub mod programs;
pub mod runner;
pub mod runtime;
pub mod serve;
pub mod speculate;
pub mod symbolic;
pub mod tape;
pub mod tensor;
pub mod trace;
pub mod tracegraph;

pub use error::{ConvertFailure, FaultStage, Result, SymbolicFault, TerraError};

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::api::{HostState, Session, Tensor, Variable};
    pub use crate::runner::Engine;
    pub use crate::config::{ExecMode, RunConfig};
    pub use crate::error::{Result, TerraError};
    pub use crate::ops::OpKind;
    pub use crate::tensor::{DType, HostTensor, Shape, TensorType};
}
