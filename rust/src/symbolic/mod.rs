//! The symbolic graph: plan IR + runtime compiler.
//!
//! The generated "symbolic graph" of the paper maps here to a **plan**: a
//! structured program over *fused segments* (straight-line runs of DL ops
//! compiled into single `XlaComputation`s at runtime via `XlaBuilder`) plus
//! plan-level communication and control operations:
//!
//! * `Feed`   — the paper's *Input Feeding* operation,
//! * `Fetch`  — the paper's *Output Fetching* operation,
//! * `Switch` — the paper's *Switch-Case* (its conditional input arrives at
//!   runtime from the PythonRunner — the *Case Select* operation is the
//!   mailbox message itself),
//! * `Assign` — staged variable update, committed at the iteration barrier.
//!
//! Fusion on/off (the ±XLA axis of Figure 5) is a segmentation parameter:
//! whole segments per computation vs one op per computation.

mod compiler;
mod plan;

pub use compiler::{compile_plan, validate_plan_artifacts, CompiledPlan, CompiledSegment};
pub use plan::{
    collect_message_nodes, executable_steps, truncation_boundary, Binding, MessageNodes,
    PlanSpec, SegId, SegmentSpec, Step,
};
