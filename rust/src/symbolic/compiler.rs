//! Runtime compilation of plan segments into fused `XlaComputation`s.
//!
//! Compiled segments are cached by a structural key, so re-generating a plan
//! after a fallback (or compiling the same layer stack twice) hits the cache
//! instead of XLA. This is the analogue of TF's graph-executor compilation
//! cache and is what keeps Terra's re-tracing overhead bounded (paper App. F).

use crate::error::{Result, TerraError};
use crate::ops::lower_op;
use crate::runtime::{ArtifactStore, Client, ExecCache, Executable};
use crate::symbolic::plan::{Binding, PlanSpec, SegmentSpec, Step};
use crate::tensor::TensorType;
use crate::tracegraph::{GraphSrc, NodeId, NodeKind, TraceGraph};
use crate::trace::ItemKey;
use std::collections::HashMap;
use std::sync::Arc;

/// A compiled segment ready for execution.
pub struct CompiledSegment {
    pub spec: SegmentSpec,
    pub exe: Executable,
}

/// A fully compiled plan: what the GraphRunner executes every iteration.
pub struct CompiledPlan {
    pub steps: Vec<Step>,
    pub segments: Vec<CompiledSegment>,
    pub graph: Arc<TraceGraph>,
    /// Number of fresh segment compilations (vs cache hits) for this plan.
    pub compiled_fresh: usize,
    /// Divergence-site split points that cut fused chains in this plan
    /// (profile-guided segment scheduling).
    pub split_points: Vec<NodeId>,
}

impl CompiledPlan {
    /// [`crate::symbolic::plan::truncation_boundary`] over the compiled
    /// segments: the first top-level step index the GraphRunner must *not*
    /// execute when a fallback diverges at `site`, or `None` when the site
    /// does not align with a boundary (whole-iteration cancel).
    pub fn truncation_boundary(&self, site: NodeId) -> Option<usize> {
        crate::symbolic::plan::truncation_boundary(
            &self.steps,
            &|id| self.segments[id.0].spec.nodes.as_slice(),
            site,
        )
    }

    /// Mailbox keys consumed by the steps from `boundary` onward — the
    /// messages a truncated GraphRunner could be blocked on and the diverged
    /// PythonRunner will never send.
    pub fn downstream_message_nodes(&self, boundary: usize) -> crate::symbolic::plan::MessageNodes {
        let mut m = crate::symbolic::plan::MessageNodes::default();
        crate::symbolic::plan::collect_message_nodes(
            &self.steps[boundary.min(self.steps.len())..],
            &|id| self.segments[id.0].spec.params.as_slice(),
            &mut m,
        );
        m
    }

    /// `(saved, cancelled)` executable-step counts for a truncation at
    /// `boundary`: segments/artifacts whose results survive the fallback vs
    /// those cancelled downstream (Switch cases counted in full — an upper
    /// bound, at most one case runs per iteration).
    pub fn split_savings(&self, boundary: usize) -> (u64, u64) {
        let b = boundary.min(self.steps.len());
        let nodes = |id: crate::symbolic::SegId| self.segments[id.0].spec.nodes.as_slice();
        (
            crate::symbolic::plan::executable_steps(&self.steps[..b], &nodes),
            crate::symbolic::plan::executable_steps(&self.steps[b..], &nodes),
        )
    }

    /// Executable steps in the whole plan (whole-iteration cancel cost).
    pub fn executable_steps(&self) -> u64 {
        let nodes = |id: crate::symbolic::SegId| self.segments[id.0].spec.nodes.as_slice();
        crate::symbolic::plan::executable_steps(&self.steps, &nodes)
    }

    /// Kernel-level cost of one plan iteration: the sum of the segments'
    /// per-executable `backend_stats().kernel_cost` (a static element-op
    /// estimate the bytecode backend computes at compile time; 0 for
    /// interpreter-backed segments). Deterministic for a given plan and
    /// backend — the speculation controller scales its re-entry patience by
    /// this, so expensive plans are not thrashed in and out of co-execution
    /// on the same evidence as cheap ones.
    pub fn kernel_cost(&self) -> u64 {
        self.segments.iter().map(|s| s.exe.backend_stats().kernel_cost).sum()
    }
}

/// Which (node, slot) sources and variables each parameter covers.
/// Dynamic params cover every observed alternative of their consumer's
/// input position; the runtime picks the value, the compiled code just sees
/// a parameter of the right type.
struct ParamCoverage {
    /// (producer node, slot) -> param index
    slots: HashMap<(NodeId, usize), usize>,
    /// variable -> param index
    vars: HashMap<crate::trace::VarId, usize>,
}

fn param_coverage(graph: &TraceGraph, spec: &SegmentSpec) -> Result<ParamCoverage> {
    let mut cov = ParamCoverage { slots: HashMap::new(), vars: HashMap::new() };
    for (i, b) in spec.params.iter().enumerate() {
        match b {
            Binding::Slot { node, slot } => {
                cov.slots.insert((*node, *slot), i);
            }
            Binding::Var(v) => {
                cov.vars.insert(*v, i);
            }
            Binding::Dynamic { consumer, pos } => {
                for v in &graph.node(*consumer).variants {
                    match v[*pos] {
                        GraphSrc::Node { node, slot } => {
                            cov.slots.insert((node, slot), i);
                        }
                        GraphSrc::Var(var) => {
                            cov.vars.insert(var, i);
                        }
                    }
                }
            }
            Binding::Const(_) => {
                return Err(TerraError::runtime("const binding cannot be a parameter"))
            }
        }
    }
    Ok(cov)
}

/// Structural cache key of a segment: op defs + internal wiring + param
/// structure. Location-independent so identical layer stacks share compiled
/// code.
fn segment_key(graph: &TraceGraph, spec: &SegmentSpec) -> Result<String> {
    let mut s = String::with_capacity(256);
    let index_of: HashMap<NodeId, usize> =
        spec.nodes.iter().enumerate().map(|(i, n)| (*n, i)).collect();
    let cov = param_coverage(graph, spec)?;
    for ty in &spec.param_types {
        s.push_str(&ty.signature());
        s.push(';');
    }
    s.push('|');
    for &n in &spec.nodes {
        let node = graph.node(n);
        if let NodeKind::Item(ItemKey::Op { def, .. }) = &node.kind {
            s.push_str(&def.cache_key());
        }
        // Wiring: where each input comes from (param index, internal node
        // index, const hash, or var).
        if let Some(v) = node.variants.first() {
            for src in v {
                match src {
                    GraphSrc::Var(id) => match cov.vars.get(id) {
                        Some(i) => s.push_str(&format!("P{i}")),
                        None => s.push_str(&format!("V{}", id.0)),
                    },
                    GraphSrc::Node { node: p, slot } => match index_of.get(p) {
                        Some(i) => s.push_str(&format!("N{i}.{slot}")),
                        None => match cov.slots.get(&(*p, *slot)) {
                            Some(i) => s.push_str(&format!("P{i}")),
                            None => s.push_str(&format!("C{}", const_sig(graph, *p))),
                        },
                    },
                }
            }
        }
        s.push(';');
    }
    s.push('>');
    for (n, slot) in &spec.outputs {
        s.push_str(&format!("{}:{slot},", index_of.get(n).map(|i| *i as i64).unwrap_or(-1)));
    }
    Ok(s)
}

fn const_sig(graph: &TraceGraph, n: NodeId) -> String {
    match &graph.node(n).kind {
        NodeKind::Item(ItemKey::Const { value_hash, ty, .. }) => {
            format!("{value_hash:x}:{}", ty.signature())
        }
        _ => "?".to_string(),
    }
}

/// Compile one segment into a fused XlaComputation.
fn compile_segment(
    client: &Client,
    cache: &ExecCache,
    graph: &TraceGraph,
    spec: &SegmentSpec,
) -> Result<(Executable, bool)> {
    // The resolved shim backend is part of the key: the process-global cache
    // outlives `XLA_SHIM_BACKEND` flips (differential tests, the interp CI
    // job), and an executable compiled under one backend must never serve
    // the other. The structural part stays split-invariant, so segments
    // untouched by a re-segmentation still hit.
    let key = format!("seg|{}|{}", xla::active_backend().name(), segment_key(graph, spec)?);
    let misses_before = cache.misses();
    let exe = cache.get_or_compile_with(&key, || {
        let builder = xla::XlaBuilder::new("segment");
        // Parameters: register each under every (node, slot) / variable it
        // covers, so body lowering finds them regardless of the variant.
        let cov = param_coverage(graph, spec)?;
        let mut built: HashMap<(NodeId, usize), xla::XlaOp> = HashMap::new();
        let mut var_params: HashMap<crate::trace::VarId, xla::XlaOp> = HashMap::new();
        let mut param_ops: Vec<xla::XlaOp> = Vec::with_capacity(spec.params.len());
        for (i, ty) in spec.param_types.iter().enumerate() {
            param_ops.push(builder.parameter(
                i as i64,
                ty.dtype.element_type(),
                &ty.shape.dims_i64(),
                &format!("p{i}"),
            )?);
        }
        for (&(n, s), &i) in &cov.slots {
            built.insert((n, s), param_ops[i].copy()?);
        }
        for (&v, &i) in &cov.vars {
            var_params.insert(v, param_ops[i].copy()?);
        }
        // Body: lower each op node in order.
        for &n in &spec.nodes {
            let node = graph.node(n);
            let NodeKind::Item(ItemKey::Op { def, .. }) = &node.kind else {
                return Err(TerraError::runtime(format!(
                    "segment contains non-op node {n:?}"
                )));
            };
            let variant = node.variants.first().ok_or_else(|| {
                TerraError::runtime(format!("node {n:?} has no dataflow variant"))
            })?;
            let mut inputs: Vec<xla::XlaOp> = Vec::with_capacity(variant.len());
            for src in variant {
                let op = match src {
                    GraphSrc::Var(v) => var_params
                        .get(v)
                        .ok_or_else(|| {
                            TerraError::runtime(format!("variable {v:?} not a segment param"))
                        })?
                        .copy()?,
                    GraphSrc::Node { node: p, slot } => match built.get(&(*p, *slot)) {
                        Some(op) => op.copy()?,
                        None => {
                            // Must be an embedded constant.
                            let cnode = graph.node(*p);
                            let value = cnode.const_value.as_ref().ok_or_else(|| {
                                TerraError::runtime(format!(
                                    "unbound segment input {p:?}:{slot}"
                                ))
                            })?;
                            let lit = value.to_literal()?;
                            let op = builder.constant_literal(&lit)?;
                            built.insert((*p, *slot), op.copy()?);
                            op
                        }
                    },
                };
                inputs.push(op);
            }
            let input_refs: Vec<&xla::XlaOp> = inputs.iter().collect();
            let outs = lower_op(&builder, &def.kind, &input_refs, &def.in_types)?;
            for (slot, op) in outs.into_iter().enumerate() {
                built.insert((n, slot), op);
            }
        }
        // Root tuple of exported outputs.
        let out_types: Vec<TensorType> = spec
            .outputs
            .iter()
            .map(|(n, slot)| graph.node(*n).out_types[*slot].clone())
            .collect();
        let mut roots: Vec<xla::XlaOp> = Vec::with_capacity(spec.outputs.len());
        for (n, slot) in &spec.outputs {
            roots.push(
                built
                    .get(&(*n, *slot))
                    .ok_or_else(|| TerraError::runtime(format!("missing output {n:?}:{slot}")))?
                    .copy()?,
            );
        }
        let comp = if roots.len() == 1 {
            builder.build(&roots[0])?
        } else {
            let root = builder.tuple(&roots)?;
            builder.build(&root)?
        };
        client.compile(&comp, out_types)
    })?;
    Ok((exe, cache.misses() > misses_before))
}

/// Check that every `Artifact` step of a plan resolves in `artifacts`.
/// Called before compiling a fresh plan, and again by the speculation plan
/// cache when a *cached* plan is reused under a different engine's store —
/// a missing artifact must fail at entry, not asynchronously mid-iteration.
pub fn validate_plan_artifacts(steps: &[Step], artifacts: &ArtifactStore) -> Result<()> {
    for s in steps {
        match s {
            Step::Artifact { name, .. } => {
                artifacts.meta(name)?;
            }
            Step::Switch { cases, .. } => {
                for c in cases {
                    validate_plan_artifacts(c, artifacts)?;
                }
            }
            _ => {}
        }
    }
    Ok(())
}

/// Compile every segment of a plan. Artifact steps are validated against the
/// artifact store (their executables are compiled lazily on first use).
pub fn compile_plan(
    client: &Client,
    cache: &ExecCache,
    artifacts: &ArtifactStore,
    graph: Arc<TraceGraph>,
    spec: PlanSpec,
) -> Result<CompiledPlan> {
    validate_plan_artifacts(&spec.steps, artifacts)?;

    let mut segments = Vec::with_capacity(spec.segments.len());
    let mut compiled_fresh = 0;
    for seg in &spec.segments {
        let (exe, fresh) = compile_segment(client, cache, &graph, seg)?;
        if fresh {
            compiled_fresh += 1;
        }
        segments.push(CompiledSegment { spec: seg.clone(), exe });
    }
    Ok(CompiledPlan {
        steps: spec.steps,
        segments,
        graph,
        compiled_fresh,
        split_points: spec.split_points,
    })
}
