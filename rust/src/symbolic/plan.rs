//! Plan IR produced by the GraphGenerator.

use crate::tensor::TensorType;
use crate::tracegraph::NodeId;
use crate::trace::VarId;

/// Index of a segment within a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SegId(pub usize);

/// How a runtime value is obtained.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Binding {
    /// Output `slot` of a statically known producer node.
    Slot { node: NodeId, slot: usize },
    /// Input `pos` of `consumer`, whose producer depends on the path taken:
    /// resolved through the PythonRunner's *variant select* message for
    /// `consumer` (the dataflow counterpart of the paper's Case Select —
    /// it names which observed dataflow variant this iteration follows).
    Dynamic { consumer: NodeId, pos: usize },
    /// Current value of a variable (staged value if assigned earlier in the
    /// same iteration, committed value otherwise).
    Var(VarId),
    /// Non-generalized constant node: embedded into compiled segments at
    /// compile time; resolved from the TraceGraph for plan-level uses.
    Const(NodeId),
}

impl Binding {
    pub fn slot(node: NodeId, slot: usize) -> Self {
        Binding::Slot { node, slot }
    }
}

/// One plan step, executed in order by the GraphRunner.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Execute a fused segment.
    Seg(SegId),
    /// Execute an AOT artifact op (its own pre-compiled executable).
    Artifact { node: NodeId, name: String, params: Vec<Binding> },
    /// Input Feeding: receive a host value from the PythonRunner into the
    /// value store under `node`.
    Feed { node: NodeId },
    /// Output Fetching: materialize `src` and send it to the PythonRunner.
    Fetch { node: NodeId, src: Binding },
    /// Stage a variable update (committed at the iteration barrier).
    Assign { var: VarId, src: Binding },
    /// Switch-Case: wait for the PythonRunner's Case Select for `node`, then
    /// execute the selected case's steps.
    Switch { node: NodeId, cases: Vec<Vec<Step>> },
}

/// An uncompiled fused segment: a straight-line run of DL op nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentSpec {
    pub id: SegId,
    /// Op nodes in execution order.
    pub nodes: Vec<NodeId>,
    /// Parameter bindings (resolved by the GraphRunner before launch).
    /// Parallel to `param_types`. `Binding::Const` never appears here.
    pub params: Vec<Binding>,
    pub param_types: Vec<TensorType>,
    /// Values exported to the store after execution (tuple order).
    pub outputs: Vec<(NodeId, usize)>,
}

/// The uncompiled plan.
#[derive(Debug, Clone, Default)]
pub struct PlanSpec {
    pub steps: Vec<Step>,
    pub segments: Vec<SegmentSpec>,
}

impl PlanSpec {
    /// Count steps recursively (diagnostics).
    pub fn count_steps(steps: &[Step]) -> (usize, usize, usize, usize, usize) {
        // (segments, feeds, fetches, assigns, switches)
        let mut c = (0, 0, 0, 0, 0);
        fn rec(steps: &[Step], c: &mut (usize, usize, usize, usize, usize)) {
            for s in steps {
                match s {
                    Step::Seg(_) | Step::Artifact { .. } => c.0 += 1,
                    Step::Feed { .. } => c.1 += 1,
                    Step::Fetch { .. } => c.2 += 1,
                    Step::Assign { .. } => c.3 += 1,
                    Step::Switch { cases, .. } => {
                        c.4 += 1;
                        for case in cases {
                            rec(case, c);
                        }
                    }
                }
            }
        }
        rec(steps, &mut c);
        c
    }

    pub fn summary(&self) -> String {
        let (segs, feeds, fetches, assigns, switches) = Self::count_steps(&self.steps);
        format!(
            "plan: {} segment-steps ({} compiled segments), {feeds} feeds, {fetches} fetches, \
             {assigns} assigns, {switches} switches",
            segs,
            self.segments.len()
        )
    }
}
