//! Plan IR produced by the GraphGenerator, plus the segment-scheduling
//! helpers partial cancellation is built on: locating the truncation
//! boundary for a divergence site and collecting the mailbox keys consumed
//! by the steps downstream of it.

use crate::tensor::TensorType;
use crate::tracegraph::NodeId;
use crate::trace::VarId;
use std::collections::HashSet;

/// Index of a segment within a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SegId(pub usize);

/// How a runtime value is obtained.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Binding {
    /// Output `slot` of a statically known producer node.
    Slot { node: NodeId, slot: usize },
    /// Input `pos` of `consumer`, whose producer depends on the path taken:
    /// resolved through the PythonRunner's *variant select* message for
    /// `consumer` (the dataflow counterpart of the paper's Case Select —
    /// it names which observed dataflow variant this iteration follows).
    Dynamic { consumer: NodeId, pos: usize },
    /// Current value of a variable (staged value if assigned earlier in the
    /// same iteration, committed value otherwise).
    Var(VarId),
    /// Non-generalized constant node: embedded into compiled segments at
    /// compile time; resolved from the TraceGraph for plan-level uses.
    Const(NodeId),
}

impl Binding {
    pub fn slot(node: NodeId, slot: usize) -> Self {
        Binding::Slot { node, slot }
    }
}

/// One plan step, executed in order by the GraphRunner.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Execute a fused segment.
    Seg(SegId),
    /// Execute an AOT artifact op (its own pre-compiled executable).
    Artifact { node: NodeId, name: String, params: Vec<Binding> },
    /// Input Feeding: receive a host value from the PythonRunner into the
    /// value store under `node`.
    Feed { node: NodeId },
    /// Output Fetching: materialize `src` and send it to the PythonRunner.
    Fetch { node: NodeId, src: Binding },
    /// Stage a variable update (committed at the iteration barrier).
    Assign { var: VarId, src: Binding },
    /// Switch-Case: wait for the PythonRunner's Case Select for `node`, then
    /// execute the selected case's steps.
    Switch { node: NodeId, cases: Vec<Vec<Step>> },
}

/// An uncompiled fused segment: a straight-line run of DL op nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentSpec {
    pub id: SegId,
    /// Op nodes in execution order.
    pub nodes: Vec<NodeId>,
    /// Parameter bindings (resolved by the GraphRunner before launch).
    /// Parallel to `param_types`. `Binding::Const` never appears here.
    pub params: Vec<Binding>,
    pub param_types: Vec<TensorType>,
    /// Values exported to the store after execution (tuple order).
    pub outputs: Vec<(NodeId, usize)>,
}

/// The uncompiled plan.
#[derive(Debug, Clone, Default)]
pub struct PlanSpec {
    pub steps: Vec<Step>,
    pub segments: Vec<SegmentSpec>,
    /// Divergence-site split points that actually cut a fused chain during
    /// generation (profile-guided segment scheduling; subset of
    /// `GenOptions::split_points`).
    pub split_points: Vec<NodeId>,
}

impl PlanSpec {
    /// Count steps recursively (diagnostics).
    pub fn count_steps(steps: &[Step]) -> (usize, usize, usize, usize, usize) {
        // (segments, feeds, fetches, assigns, switches)
        let mut c = (0, 0, 0, 0, 0);
        fn rec(steps: &[Step], c: &mut (usize, usize, usize, usize, usize)) {
            for s in steps {
                match s {
                    Step::Seg(_) | Step::Artifact { .. } => c.0 += 1,
                    Step::Feed { .. } => c.1 += 1,
                    Step::Fetch { .. } => c.2 += 1,
                    Step::Assign { .. } => c.3 += 1,
                    Step::Switch { cases, .. } => {
                        c.4 += 1;
                        for case in cases {
                            rec(case, c);
                        }
                    }
                }
            }
        }
        rec(steps, &mut c);
        c
    }

    pub fn summary(&self) -> String {
        let (segs, feeds, fetches, assigns, switches) = Self::count_steps(&self.steps);
        format!(
            "plan: {} segment-steps ({} compiled segments), {feeds} feeds, {fetches} fetches, \
             {assigns} assigns, {switches} switches",
            segs,
            self.segments.len()
        )
    }

    /// [`truncation_boundary`] over this spec's own segments.
    pub fn truncation_boundary(&self, site: NodeId) -> Option<usize> {
        truncation_boundary(&self.steps, &|id: SegId| self.segments[id.0].nodes.as_slice(), site)
    }
}

/// Mailbox keys consumed by a run of plan steps (recursively through Switch
/// cases): Feed nodes, Switch (case-select) nodes and variant-select
/// consumers. The engine uses the set for the steps *downstream* of a
/// truncation boundary to wake a GraphRunner blocked on a message the
/// diverged PythonRunner will never send.
#[derive(Debug, Default)]
pub struct MessageNodes {
    pub feeds: HashSet<NodeId>,
    pub cases: HashSet<NodeId>,
    pub variants: HashSet<NodeId>,
}

/// Collect [`MessageNodes`] for `steps`. `seg_params` resolves a segment id
/// to its parameter bindings (spec- or compiled-plan-side).
pub fn collect_message_nodes<'p>(
    steps: &'p [Step],
    seg_params: &impl Fn(SegId) -> &'p [Binding],
    out: &mut MessageNodes,
) {
    let mut dynamic = |b: &Binding, out: &mut MessageNodes| {
        if let Binding::Dynamic { consumer, .. } = b {
            out.variants.insert(*consumer);
        }
    };
    for s in steps {
        match s {
            Step::Seg(id) => {
                for b in seg_params(*id) {
                    dynamic(b, out);
                }
            }
            Step::Artifact { params, .. } => {
                for b in params {
                    dynamic(b, out);
                }
            }
            Step::Feed { node } => {
                out.feeds.insert(*node);
            }
            Step::Fetch { src, .. } | Step::Assign { src, .. } => dynamic(src, out),
            Step::Switch { node, cases } => {
                out.cases.insert(*node);
                for c in cases {
                    collect_message_nodes(c, seg_params, out);
                }
            }
        }
    }
}

/// Truncation boundary for a divergence at `site` — the walker's position at
/// the fallback, i.e. the last node the PythonRunner *validated*. Returns
/// the index one past the last top-level step whose work the iteration fully
/// covered, so the GraphRunner may finish `steps[..boundary]` and only
/// `steps[boundary..]` is cancelled:
///
/// * `site` is the **last** node of a top-level segment (a split boundary —
///   natural or cut there by profile-guided splitting) → just after it;
/// * `site` is a top-level feed / fetch / artifact step → just after it;
/// * `site` is a branch node or anywhere inside a top-level Switch → the
///   Switch itself (its case select never arrives, or the case body is only
///   partially validated);
/// * `site` sits mid-segment (the un-split case) or is unknown → `None`:
///   the whole in-flight iteration must be cancelled.
pub fn truncation_boundary<'p>(
    steps: &'p [Step],
    seg_nodes: &impl Fn(SegId) -> &'p [NodeId],
    site: NodeId,
) -> Option<usize> {
    for (i, s) in steps.iter().enumerate() {
        match s {
            Step::Seg(id) => {
                let nodes = seg_nodes(*id);
                if nodes.last() == Some(&site) {
                    return Some(i + 1);
                }
                if nodes.contains(&site) {
                    return None; // mid-segment: boundary misaligned
                }
            }
            Step::Artifact { node, .. } | Step::Feed { node } | Step::Fetch { node, .. } => {
                if *node == site {
                    return Some(i + 1);
                }
            }
            Step::Assign { .. } => {}
            Step::Switch { node, cases } => {
                if *node == site || switch_subtree_contains(cases, seg_nodes, site) {
                    return Some(i);
                }
            }
        }
    }
    None
}

pub(crate) fn switch_subtree_contains<'p>(
    cases: &'p [Vec<Step>],
    seg_nodes: &impl Fn(SegId) -> &'p [NodeId],
    site: NodeId,
) -> bool {
    cases.iter().flatten().any(|s| match s {
        Step::Seg(id) => seg_nodes(*id).contains(&site),
        Step::Artifact { node, .. } | Step::Feed { node } | Step::Fetch { node, .. } => {
            *node == site
        }
        Step::Assign { .. } => false,
        Step::Switch { node, cases } => {
            *node == site || switch_subtree_contains(cases, seg_nodes, site)
        }
    })
}

/// Count executable steps (non-empty segments + artifact calls) in `steps`,
/// recursing into every Switch case. An upper bound on per-iteration work:
/// at most one case of each Switch runs per iteration.
pub fn executable_steps<'p>(steps: &'p [Step], seg_nodes: &impl Fn(SegId) -> &'p [NodeId]) -> u64 {
    let mut n = 0;
    for s in steps {
        match s {
            Step::Seg(id) => {
                if !seg_nodes(*id).is_empty() {
                    n += 1;
                }
            }
            Step::Artifact { .. } => n += 1,
            Step::Switch { cases, .. } => {
                for c in cases {
                    n += executable_steps(c, seg_nodes);
                }
            }
            _ => {}
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built plan shape:
    ///   0: Feed{5}
    ///   1: Seg(0)            nodes [1, 2]
    ///   2: Fetch{6}
    ///   3: Seg(1)            nodes [3, 4], one Dynamic param (consumer 3)
    ///   4: Switch{7}         case 0 = [Feed{8}], case 1 = []
    fn sample() -> PlanSpec {
        let seg = |id: usize, nodes: Vec<usize>, params: Vec<Binding>| SegmentSpec {
            id: SegId(id),
            nodes: nodes.into_iter().map(NodeId).collect(),
            params,
            param_types: vec![],
            outputs: vec![(NodeId(2), 0)],
        };
        PlanSpec {
            steps: vec![
                Step::Feed { node: NodeId(5) },
                Step::Seg(SegId(0)),
                Step::Fetch { node: NodeId(6), src: Binding::slot(NodeId(2), 0) },
                Step::Seg(SegId(1)),
                Step::Switch {
                    node: NodeId(7),
                    cases: vec![vec![Step::Feed { node: NodeId(8) }], vec![]],
                },
            ],
            segments: vec![
                seg(0, vec![1, 2], vec![Binding::slot(NodeId(5), 0)]),
                seg(1, vec![3, 4], vec![Binding::Dynamic { consumer: NodeId(3), pos: 0 }]),
            ],
            split_points: vec![NodeId(2)],
        }
    }

    #[test]
    fn boundary_aligns_only_at_segment_ends() {
        let p = sample();
        // Last node of a segment: the prefix through that segment survives.
        assert_eq!(p.truncation_boundary(NodeId(2)), Some(2));
        assert_eq!(p.truncation_boundary(NodeId(4)), Some(4));
        // Mid-segment site: misaligned, whole-iteration cancel.
        assert_eq!(p.truncation_boundary(NodeId(1)), None);
        assert_eq!(p.truncation_boundary(NodeId(3)), None);
        // Feed / fetch sites survive through their own step.
        assert_eq!(p.truncation_boundary(NodeId(5)), Some(1));
        assert_eq!(p.truncation_boundary(NodeId(6)), Some(3));
        // Branch node or anything inside the Switch: stop before the Switch.
        assert_eq!(p.truncation_boundary(NodeId(7)), Some(4));
        assert_eq!(p.truncation_boundary(NodeId(8)), Some(4));
        // Unknown site.
        assert_eq!(p.truncation_boundary(NodeId(99)), None);
    }

    #[test]
    fn downstream_message_nodes_cover_nested_cases() {
        let p = sample();
        let mut m = MessageNodes::default();
        let params = |id: SegId| p.segments[id.0].params.as_slice();
        collect_message_nodes(&p.steps[3..], &params, &mut m);
        assert!(m.variants.contains(&NodeId(3)), "dynamic param consumer: {m:?}");
        assert!(m.cases.contains(&NodeId(7)), "switch case select: {m:?}");
        assert!(m.feeds.contains(&NodeId(8)), "feed nested in a case: {m:?}");
        assert!(!m.feeds.contains(&NodeId(5)), "upstream feed excluded: {m:?}");
    }

    #[test]
    fn executable_step_counts() {
        let p = sample();
        let nodes = |id: SegId| p.segments[id.0].nodes.as_slice();
        assert_eq!(executable_steps(&p.steps, &nodes), 2);
        assert_eq!(executable_steps(&p.steps[..2], &nodes), 1);
        assert_eq!(executable_steps(&p.steps[4..], &nodes), 0, "feeds are not compute");
    }
}
