//! Flight-recorder tracing for the co-execution engine.
//!
//! An always-compiled, off-by-default observability layer: a fixed-capacity
//! ring buffer of timeline events (the *flight recorder*) fed by
//! instrumentation points in both runners, the engine control path, and the
//! shim, plus streaming log-scale latency histograms ([`Hist`]) that the
//! engine's [`Breakdown`](crate::metrics::Breakdown) owns.
//!
//! Design contract (see `obs/README.md` for the long form):
//!
//! - **Off by default, cheap when off.** Every emit helper first reads one
//!   relaxed atomic; when tracing is disabled nothing is timestamped, locked,
//!   or heap-allocated on the hot path.
//! - **Recording only.** Instrumentation never changes control flow,
//!   rendezvous order, or results — a traced run is bit-identical to an
//!   untraced run (enforced by `tests/obs_tracing.rs`).
//! - **Bounded.** The ring holds [`RING_CAPACITY`] events and overwrites the
//!   oldest, so a week-long run records the *recent* past — exactly what a
//!   fault dump needs.
//!
//! Enable with `TERRA_TRACE=chrome:<path>` (strictly parsed: junk is a loud
//! config error), the `--trace chrome:<path>` CLI flag, or the `trace` key of
//! a JSON run config. [`export`] writes Chrome trace-event JSON loadable in
//! Perfetto / `chrome://tracing`, with the PythonRunner, GraphRunner, and
//! engine control path as separate named tracks. On any contained
//! `SymbolicFault` the engine calls [`fault_dump`], serializing the last
//! [`FAULT_DUMP_EVENTS`] events next to the trace path.

use crate::config::Json;
use crate::error::{Result, TerraError};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::{Duration, Instant};

/// Ring-buffer capacity in events (~4.5 MB resident once tracing is on).
pub const RING_CAPACITY: usize = 65_536;
/// How many trailing events a fault dump serializes.
pub const FAULT_DUMP_EVENTS: usize = 256;

// ---- taxonomy --------------------------------------------------------------

/// Timeline an event belongs to. Each track renders as one Chrome trace
/// thread (`tid`) so Perfetto shows the two runners and the engine control
/// path as separate swim lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Track {
    /// The imperative side: skeleton step execution and fetch waits.
    Python,
    /// The symbolic side: GraphRunner iterations, segments, kernels.
    Graph,
    /// Engine control: trace/merge/optimize/compile, re-entry, faults.
    Engine,
}

impl Track {
    fn tid(self) -> u64 {
        match self {
            Track::Python => 1,
            Track::Graph => 2,
            Track::Engine => 3,
        }
    }

    fn thread_name(self) -> &'static str {
        match self {
            Track::Python => "PythonRunner",
            Track::Graph => "GraphRunner",
            Track::Engine => "Engine",
        }
    }
}

/// Interval events: phases with a start time and a duration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Imperative execution of one step (eager or co-execution skeleton).
    PyExec,
    /// Imperative execution of one step while (re)tracing.
    TraceExec,
    /// Skeleton blocked on a fetch rendezvous (`materialize`).
    PyFetchWait,
    /// One whole GraphRunner iteration (encloses the segment spans).
    GraphIter,
    /// GraphRunner blocked on run-ahead allowance or the commit barrier.
    GraphStall,
    /// One compiled segment execution (args: segment id, kernel cost).
    SegExec,
    /// One shim kernel execution inside a segment (args: instructions,
    /// kernel cost), reported via `xla::take_last_exec`.
    KernelExec,
    /// GraphRunner blocked on a feed rendezvous.
    FeedWait,
    /// Merging a fresh trace into the TraceGraph.
    TraceMerge,
    /// Optimizer pass pipeline over the merged graph.
    Optimize,
    /// Plan generation (segmentation / scheduling).
    PlanGen,
    /// Segment compilation through the shim.
    SegmentCompile,
    /// Co-execution (re-)entry: plan lookup/build plus runner spawn.
    EnterCoexec,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::PyExec => "py_exec",
            SpanKind::TraceExec => "trace_exec",
            SpanKind::PyFetchWait => "py_fetch_wait",
            SpanKind::GraphIter => "graph_iter",
            SpanKind::GraphStall => "graph_stall",
            SpanKind::SegExec => "segment_exec",
            SpanKind::KernelExec => "kernel",
            SpanKind::FeedWait => "feed_wait",
            SpanKind::TraceMerge => "trace_merge",
            SpanKind::Optimize => "optimize",
            SpanKind::PlanGen => "plan_gen",
            SpanKind::SegmentCompile => "segment_compile",
            SpanKind::EnterCoexec => "enter_coexec",
        }
    }

    fn arg_names(self) -> (&'static str, &'static str) {
        match self {
            SpanKind::PyExec | SpanKind::TraceExec | SpanKind::Optimize => ("", ""),
            SpanKind::PyFetchWait | SpanKind::FeedWait => ("node", ""),
            SpanKind::GraphIter => ("steps", ""),
            SpanKind::GraphStall => ("phase", ""),
            SpanKind::SegExec => ("segment", "kernel_cost"),
            SpanKind::KernelExec => ("instructions", "kernel_cost"),
            SpanKind::TraceMerge => ("changed", ""),
            SpanKind::PlanGen => ("segments", ""),
            SpanKind::SegmentCompile => ("compiled_fresh", ""),
            SpanKind::EnterCoexec => ("segments", "cache_hit"),
        }
    }
}

/// Point-in-time events (Chrome `ph:"i"` instants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstantKind {
    /// Divergence fallback: the skeleton left the traced path.
    Fallback,
    /// Fallback truncated in-flight work at a split boundary instead of
    /// cancelling the whole iteration window.
    PartialCancel,
    /// Uncommitted iterations replayed imperatively after a fault.
    Replay,
    /// A watchdog deadline expired while waiting on the symbolic side.
    WatchdogFire,
    /// A plan accumulated a quarantine strike.
    QuarantineStrike,
    /// Co-execution entry skipped during a plan's exponential backoff.
    QuarantineBackoff,
    /// A plan crossed the strike limit and is pinned to eager execution.
    Quarantined,
    /// The deterministic fault harness injected a fault.
    FaultInjected,
    /// A contained `SymbolicFault` reached the engine's recovery path.
    Fault,
    /// Plan-cache lookup outcomes on co-execution entry.
    PlanCacheHit,
    PlanCacheMiss,
    /// Re-entry controller verdicts on a stable trace.
    ReentryGo,
    ReentryDefer,
}

impl InstantKind {
    pub fn name(self) -> &'static str {
        match self {
            InstantKind::Fallback => "fallback",
            InstantKind::PartialCancel => "partial_cancel",
            InstantKind::Replay => "imperative_replay",
            InstantKind::WatchdogFire => "watchdog_fire",
            InstantKind::QuarantineStrike => "quarantine_strike",
            InstantKind::QuarantineBackoff => "quarantine_backoff",
            InstantKind::Quarantined => "quarantined",
            InstantKind::FaultInjected => "fault_injected",
            InstantKind::Fault => "fault",
            InstantKind::PlanCacheHit => "plan_cache_hit",
            InstantKind::PlanCacheMiss => "plan_cache_miss",
            InstantKind::ReentryGo => "reentry_go",
            InstantKind::ReentryDefer => "reentry_defer",
        }
    }

    fn arg_names(self) -> (&'static str, &'static str) {
        match self {
            InstantKind::Fallback => ("site", ""),
            InstantKind::PartialCancel => ("boundary", ""),
            InstantKind::Replay => ("from", "to"),
            InstantKind::WatchdogFire => ("node", "timeout_ms"),
            InstantKind::QuarantineStrike => ("strikes", "quarantined"),
            InstantKind::QuarantineBackoff | InstantKind::Quarantined => ("", ""),
            InstantKind::FaultInjected => ("site", "kind"),
            InstantKind::Fault => ("stage", "panicked"),
            InstantKind::PlanCacheHit | InstantKind::PlanCacheMiss => ("", ""),
            InstantKind::ReentryGo | InstantKind::ReentryDefer => ("stable_run", "plan_cached"),
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub enum EventKind {
    Span(SpanKind),
    Instant(InstantKind),
}

/// One recorded timeline event. `Copy` with `&'static str` names only — the
/// ring never owns heap data, so recording is a plain slot write.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub track: Track,
    pub kind: EventKind,
    /// Serve-session the event belongs to (0 = the standalone engine; serve
    /// sessions tag their runner threads via [`set_session`]).
    pub session: u64,
    /// Training-loop iteration the event belongs to (0 when not applicable).
    pub iter: u64,
    /// Start time, nanoseconds since the process trace epoch.
    pub t_ns: u64,
    /// Duration in nanoseconds; 0 for instants.
    pub dur_ns: u64,
    /// Two kind-specific arguments (see `arg_names`); 0 when unused.
    pub a: u64,
    pub b: u64,
}

impl Event {
    pub fn name(&self) -> &'static str {
        match self.kind {
            EventKind::Span(k) => k.name(),
            EventKind::Instant(k) => k.name(),
        }
    }

    pub fn is_instant(&self) -> bool {
        matches!(self.kind, EventKind::Instant(_))
    }

    /// Chrome `tid` for this event: session 0 keeps the bare track tids
    /// (1/2/3) so single-engine traces are unchanged; serve sessions get a
    /// disjoint namespaced range (`session*10 + track`) so each session's
    /// runners render as their own swim lanes.
    fn chrome_tid(&self) -> u64 {
        if self.session == 0 {
            self.track.tid()
        } else {
            self.session * 10 + self.track.tid()
        }
    }

    /// Chrome trace-event object (`ph:"X"` complete span / `ph:"i"` instant;
    /// `ts`/`dur` in microseconds as the format requires).
    fn chrome_json(&self) -> Json {
        let (an, bn) = match self.kind {
            EventKind::Span(k) => k.arg_names(),
            EventKind::Instant(k) => k.arg_names(),
        };
        let mut args = BTreeMap::new();
        args.insert("iter".to_string(), Json::Num(self.iter as f64));
        if self.session != 0 {
            args.insert("session".to_string(), Json::Num(self.session as f64));
        }
        if !an.is_empty() {
            args.insert(an.to_string(), Json::Num(self.a as f64));
        }
        if !bn.is_empty() {
            args.insert(bn.to_string(), Json::Num(self.b as f64));
        }
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name().to_string()));
        m.insert("pid".to_string(), Json::Num(1.0));
        m.insert("tid".to_string(), Json::Num(self.chrome_tid() as f64));
        m.insert("ts".to_string(), Json::Num(self.t_ns as f64 / 1000.0));
        match self.kind {
            EventKind::Span(_) => {
                m.insert("ph".to_string(), Json::Str("X".to_string()));
                m.insert("dur".to_string(), Json::Num(self.dur_ns as f64 / 1000.0));
            }
            EventKind::Instant(_) => {
                m.insert("ph".to_string(), Json::Str("i".to_string()));
                m.insert("s".to_string(), Json::Str("t".to_string()));
            }
        }
        m.insert("args".to_string(), Json::Obj(args));
        Json::Obj(m)
    }
}

// ---- recorder --------------------------------------------------------------

/// Trace sink configuration. Only the Chrome trace-event format exists today,
/// so a config is a validated output path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceConfig {
    pub path: String,
}

impl TraceConfig {
    /// Strict spec parser: `chrome:<nonempty path>` or a loud config error
    /// naming `source` (the env knob, CLI flag, or JSON key it came from).
    pub fn parse(source: &str, raw: &str) -> Result<TraceConfig> {
        match raw.split_once(':') {
            Some(("chrome", path)) if !path.is_empty() => {
                Ok(TraceConfig { path: path.to_string() })
            }
            _ => Err(TerraError::Config(format!(
                "{source}: expected `chrome:<path>`, got `{raw}`"
            ))),
        }
    }
}

struct Ring {
    buf: Vec<Event>,
    /// Oldest-slot index once the buffer has wrapped.
    head: usize,
}

struct Recorder {
    enabled: AtomicBool,
    ring: Mutex<Ring>,
    cfg: Mutex<Option<TraceConfig>>,
    fault_dumps: AtomicU64,
}

fn recorder() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| Recorder {
        enabled: AtomicBool::new(false),
        ring: Mutex::new(Ring { buf: Vec::new(), head: 0 }),
        cfg: Mutex::new(None),
        fault_dumps: AtomicU64::new(0),
    })
}

/// Poison-tolerant lock: fault containment catches panics elsewhere in the
/// process, and the recorder must keep recording through them.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (monotonic).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

thread_local! {
    /// Serve-session id stamped onto events recorded by this thread.
    static CURRENT_SESSION: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Tag every event subsequently recorded *on this thread* with a
/// serve-session id. Session 0 (the default) is the standalone engine; the
/// serve runtime assigns ids from 1 and calls this on each session's
/// PythonRunner thread (the GraphRunner spawn path propagates it). Purely a
/// labelling concern — recording behaviour is identical either way.
pub fn set_session(id: u64) {
    CURRENT_SESSION.with(|c| c.set(id));
}

/// The serve-session id events recorded on this thread carry (see
/// [`set_session`]).
pub fn current_session() -> u64 {
    CURRENT_SESSION.with(|c| c.get())
}

/// Whether event recording is on. The one check every emit helper makes
/// first; a single relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    recorder().enabled.load(Ordering::Relaxed)
}

/// Install (or with `None`, uninstall) the trace configuration. Installing
/// preallocates the ring so [`record`-path] pushes never allocate; existing
/// events are kept (use [`clear`] for a fresh session).
pub fn install(cfg: Option<TraceConfig>) {
    let r = recorder();
    let on = cfg.is_some();
    if on {
        let mut ring = lock(&r.ring);
        let want = RING_CAPACITY.saturating_sub(ring.buf.len());
        ring.buf.reserve_exact(want);
        let _ = epoch();
    }
    *lock(&r.cfg) = cfg;
    r.enabled.store(on, Ordering::Relaxed);
}

/// The installed trace configuration, if any.
pub fn config() -> Option<TraceConfig> {
    lock(&recorder().cfg).clone()
}

/// Install from `TERRA_TRACE` unless a config is already installed (an
/// explicit `--trace` / JSON `trace` wins over the environment). Called on
/// engine construction so every binary honours the knob; junk values are a
/// hard error via the strict `config::env` parser.
pub fn init_from_env() -> Result<()> {
    if config().is_some() {
        return Ok(());
    }
    if let Some(cfg) = crate::config::env::parse_env_trace()? {
        install(Some(cfg));
    }
    Ok(())
}

/// Drop all recorded events (the config and enable state stay).
pub fn clear() {
    let mut ring = lock(&recorder().ring);
    ring.buf.clear();
    ring.head = 0;
}

fn record(ev: Event) {
    let r = recorder();
    if !r.enabled.load(Ordering::Relaxed) {
        return;
    }
    let mut ring = lock(&r.ring);
    if ring.buf.len() < RING_CAPACITY {
        ring.buf.push(ev);
    } else {
        let h = ring.head;
        ring.buf[h] = ev;
        ring.head = (h + 1) % RING_CAPACITY;
    }
}

/// Snapshot of the recorded events in chronological (record) order.
pub fn events() -> Vec<Event> {
    let ring = lock(&recorder().ring);
    let mut out = Vec::with_capacity(ring.buf.len());
    out.extend_from_slice(&ring.buf[ring.head..]);
    out.extend_from_slice(&ring.buf[..ring.head]);
    out
}

/// Drain the ring: snapshot then clear (test hygiene between runs).
pub fn take_events() -> Vec<Event> {
    let out = events();
    clear();
    out
}

// ---- emit helpers ----------------------------------------------------------

/// Record an instant event.
pub fn instant(track: Track, kind: InstantKind, iter: u64, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    record(Event {
        track,
        kind: EventKind::Instant(kind),
        session: current_session(),
        iter,
        t_ns: now_ns(),
        dur_ns: 0,
        a,
        b,
    });
}

/// Record a span from explicit epoch-relative times (used for shim kernel
/// spans whose duration is reported after the fact).
pub fn span_raw(track: Track, kind: SpanKind, iter: u64, t_ns: u64, dur_ns: u64, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    record(Event {
        track,
        kind: EventKind::Span(kind),
        session: current_session(),
        iter,
        t_ns,
        dur_ns,
        a,
        b,
    });
}

/// Record a span that started at `start` and ends now.
pub fn span_since(track: Track, kind: SpanKind, iter: u64, start: Instant, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    let dur = start.elapsed().as_nanos() as u64;
    let end = now_ns();
    span_raw(track, kind, iter, end.saturating_sub(dur), dur, a, b);
}

/// RAII span: records on drop, so early `?` returns still close the
/// interval. Inert (no timestamp taken) when tracing is disabled.
pub struct SpanGuard {
    start: Option<Instant>,
    track: Track,
    kind: SpanKind,
    iter: u64,
    a: u64,
    b: u64,
}

/// Open a [`SpanGuard`].
pub fn span(track: Track, kind: SpanKind, iter: u64, a: u64, b: u64) -> SpanGuard {
    SpanGuard { start: enabled().then(Instant::now), track, kind, iter, a, b }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            span_since(self.track, self.kind, self.iter, start, self.a, self.b);
        }
    }
}

// ---- exporters -------------------------------------------------------------

fn meta_event(tid: u64, name: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(name.to_string()));
    let mut m = BTreeMap::new();
    m.insert(
        "name".to_string(),
        Json::Str(if tid == 0 { "process_name" } else { "thread_name" }.to_string()),
    );
    m.insert("ph".to_string(), Json::Str("M".to_string()));
    m.insert("pid".to_string(), Json::Num(1.0));
    m.insert("tid".to_string(), Json::Num(tid as f64));
    m.insert("args".to_string(), Json::Obj(args));
    Json::Obj(m)
}

/// Render events as a Chrome trace-event JSON document (Perfetto /
/// `chrome://tracing` compatible): process/thread name metadata, then the
/// events sorted by start time so spans nest visually. Session 0's tracks
/// keep their bare names and tids; every serve session present in the event
/// stream additionally gets its own `S<id> <Track>` lanes.
pub fn chrome_trace(events: &[Event]) -> Json {
    let mut sorted: Vec<&Event> = events.iter().collect();
    sorted.sort_by_key(|e| e.t_ns);
    let mut arr = vec![meta_event(0, "terra")];
    for track in [Track::Python, Track::Graph, Track::Engine] {
        arr.push(meta_event(track.tid(), track.thread_name()));
    }
    let sessions: std::collections::BTreeSet<u64> =
        events.iter().map(|e| e.session).filter(|&s| s != 0).collect();
    for s in sessions {
        for track in [Track::Python, Track::Graph, Track::Engine] {
            arr.push(meta_event(
                s * 10 + track.tid(),
                &format!("S{s} {}", track.thread_name()),
            ));
        }
    }
    arr.extend(sorted.iter().map(|e| e.chrome_json()));
    let mut m = BTreeMap::new();
    m.insert("traceEvents".to_string(), Json::Arr(arr));
    m.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(m)
}

/// Write the Chrome trace to the configured path. `Ok(None)` when tracing is
/// not installed.
pub fn export() -> Result<Option<String>> {
    let Some(cfg) = config() else { return Ok(None) };
    let doc = chrome_trace(&events());
    std::fs::write(&cfg.path, doc.to_string())?;
    Ok(Some(cfg.path))
}

/// Serialize the last [`FAULT_DUMP_EVENTS`] ring events next to the trace
/// path (`<path>.fault<k>.json`) so a contained fault ships its timeline
/// context. Returns the dump path, or `None` when tracing is off or the
/// write fails — a failed dump must never escalate the fault it documents.
pub fn fault_dump(stage: &str, message: &str) -> Option<String> {
    let cfg = config()?;
    let evs = events();
    let tail = &evs[evs.len().saturating_sub(FAULT_DUMP_EVENTS)..];
    let k = recorder().fault_dumps.fetch_add(1, Ordering::Relaxed);
    let path = format!("{}.fault{k}.json", cfg.path);
    let mut m = BTreeMap::new();
    m.insert("stage".to_string(), Json::Str(stage.to_string()));
    m.insert("message".to_string(), Json::Str(message.to_string()));
    m.insert(
        "events".to_string(),
        Json::Arr(tail.iter().map(Event::chrome_json).collect()),
    );
    std::fs::write(&path, Json::Obj(m).to_string()).ok()?;
    Some(path)
}

// ---- histograms ------------------------------------------------------------

/// Streaming latency histogram: 64 power-of-two buckets over nanoseconds
/// (bucket `i` holds values in `[2^i, 2^(i+1))`), lock-free relaxed counts.
/// Percentiles report the bucket midpoint, so they carry log2-bucket
/// resolution (±50%) — plenty for p50/p90/p99 latency lines, constant
/// memory, and no per-sample allocation.
#[derive(Debug)]
pub struct Hist {
    buckets: [AtomicU64; 64],
}

impl Default for Hist {
    fn default() -> Self {
        Hist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl Hist {
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        let idx = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Percentile `p` in `[0, 1]` as the midpoint of the covering bucket,
    /// in nanoseconds; 0 when the histogram is empty.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << i) + ((1u64 << i) >> 1);
            }
        }
        (1u64 << 63) + (1u64 << 62)
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile_ns(p) as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The recorder is process-global state; tests that touch it serialize.
    fn guard() -> MutexGuard<'static, ()> {
        static G: OnceLock<Mutex<()>> = OnceLock::new();
        lock(G.get_or_init(|| Mutex::new(())))
    }

    #[test]
    fn disabled_recorder_stays_empty() {
        let _g = guard();
        install(None);
        clear();
        instant(Track::Engine, InstantKind::Fallback, 3, 7, 0);
        span_since(Track::Python, SpanKind::PyExec, 3, Instant::now(), 0, 0);
        drop(span(Track::Graph, SpanKind::GraphIter, 3, 0, 0));
        assert!(events().is_empty());
    }

    #[test]
    fn ring_records_and_wraps() {
        let _g = guard();
        install(Some(TraceConfig { path: "unused".into() }));
        clear();
        for i in 0..(RING_CAPACITY as u64 + 10) {
            instant(Track::Engine, InstantKind::FaultInjected, i, 0, 0);
        }
        let evs = take_events();
        install(None);
        assert_eq!(evs.len(), RING_CAPACITY);
        // Oldest events were overwritten; order stays chronological.
        assert_eq!(evs.first().unwrap().iter, 10);
        assert_eq!(evs.last().unwrap().iter, RING_CAPACITY as u64 + 9);
        assert!(evs.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    }

    #[test]
    fn spans_measure_and_instants_do_not() {
        let _g = guard();
        install(Some(TraceConfig { path: "unused".into() }));
        clear();
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        span_since(Track::Graph, SpanKind::SegExec, 2, t0, 4, 99);
        instant(Track::Engine, InstantKind::Fault, 2, 1, 0);
        let evs = take_events();
        install(None);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name(), "segment_exec");
        assert!(!evs[0].is_instant());
        assert!(evs[0].dur_ns >= 5_000_000, "span too short: {}", evs[0].dur_ns);
        assert_eq!((evs[0].a, evs[0].b), (4, 99));
        assert!(evs[1].is_instant());
        assert_eq!(evs[1].name(), "fault");
    }

    #[test]
    fn trace_spec_parses_strictly() {
        assert_eq!(
            TraceConfig::parse("TERRA_TRACE", "chrome:/tmp/t.json").unwrap(),
            TraceConfig { path: "/tmp/t.json".into() }
        );
        for junk in ["", "chrome", "chrome:", "perfetto:/x", "yes"] {
            let err = TraceConfig::parse("TERRA_TRACE", junk).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("TERRA_TRACE"), "error must name the knob: {msg}");
        }
    }

    #[test]
    fn chrome_export_shape() {
        let evs = vec![
            Event {
                track: Track::Graph,
                kind: EventKind::Span(SpanKind::GraphIter),
                session: 0,
                iter: 1,
                t_ns: 2_000,
                dur_ns: 10_000,
                a: 0,
                b: 0,
            },
            Event {
                track: Track::Graph,
                kind: EventKind::Span(SpanKind::SegExec),
                session: 0,
                iter: 1,
                t_ns: 3_000,
                dur_ns: 4_000,
                a: 0,
                b: 12,
            },
            Event {
                track: Track::Engine,
                kind: EventKind::Instant(InstantKind::Fallback),
                session: 0,
                iter: 1,
                t_ns: 9_000,
                dur_ns: 0,
                a: 5,
                b: 0,
            },
        ];
        let doc = Json::parse(&chrome_trace(&evs).to_string()).unwrap();
        let arr = doc.arr_field("traceEvents").unwrap();
        // 1 process + 3 thread metadata records, then the events.
        assert_eq!(arr.len(), 4 + evs.len());
        // Thread names live in the metadata records' args.
        let threads: Vec<&str> = arr
            .iter()
            .filter(|e| e.str_field("name").ok() == Some("thread_name"))
            .map(|e| e.get("args").unwrap().str_field("name").unwrap())
            .collect();
        assert!(threads.contains(&"PythonRunner") && threads.contains(&"GraphRunner"));
        let named = |want: &'static str| {
            arr.iter().find(move |e| e.str_field("name").ok() == Some(want)).unwrap()
        };
        let seg = named("segment_exec");
        assert_eq!(seg.str_field("ph").unwrap(), "X");
        assert_eq!(seg.get("ts").unwrap().as_f64(), Some(3.0));
        assert_eq!(seg.get("dur").unwrap().as_f64(), Some(4.0));
        assert_eq!(
            seg.get("args").unwrap().get("kernel_cost").unwrap().as_f64(),
            Some(12.0)
        );
        let fb = named("fallback");
        assert_eq!(fb.str_field("ph").unwrap(), "i");
    }

    #[test]
    fn session_tags_namespace_chrome_tids() {
        let _g = guard();
        install(Some(TraceConfig { path: "unused".into() }));
        clear();
        // Default thread state is session 0 (the standalone engine).
        assert_eq!(current_session(), 0);
        instant(Track::Engine, InstantKind::PlanCacheHit, 1, 0, 0);
        set_session(2);
        instant(Track::Python, InstantKind::Fallback, 1, 0, 0);
        set_session(0);
        let evs = take_events();
        install(None);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].session, 0);
        assert_eq!(evs[1].session, 2);

        let doc = Json::parse(&chrome_trace(&evs).to_string()).unwrap();
        let arr = doc.arr_field("traceEvents").unwrap();
        // Session 0's event keeps the bare engine tid; session 2's lands on
        // the namespaced range and is arg-tagged with its session id.
        let hit = arr
            .iter()
            .find(|e| e.str_field("name").ok() == Some("plan_cache_hit"))
            .unwrap();
        assert_eq!(hit.get("tid").unwrap().as_f64(), Some(3.0));
        assert!(hit.get("args").unwrap().get("session").is_none());
        let fb = arr
            .iter()
            .find(|e| e.str_field("name").ok() == Some("fallback"))
            .unwrap();
        assert_eq!(fb.get("tid").unwrap().as_f64(), Some(21.0));
        assert_eq!(
            fb.get("args").unwrap().get("session").unwrap().as_f64(),
            Some(2.0)
        );
        // The serve session gets its own named swim lanes.
        let threads: Vec<&str> = arr
            .iter()
            .filter(|e| e.str_field("name").ok() == Some("thread_name"))
            .map(|e| e.get("args").unwrap().str_field("name").unwrap())
            .collect();
        assert!(threads.contains(&"PythonRunner"), "{threads:?}");
        assert!(threads.contains(&"S2 PythonRunner"), "{threads:?}");
        assert!(threads.contains(&"S2 Engine"), "{threads:?}");
    }

    #[test]
    fn histogram_percentiles_bracket_the_data() {
        let h = Hist::default();
        assert_eq!(h.percentile_ns(0.99), 0);
        // 90 fast samples (~1µs) and 10 slow ones (~1ms).
        for _ in 0..90 {
            h.record_ns(1_000);
        }
        for _ in 0..10 {
            h.record_ns(1_000_000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_ns(0.50);
        let p99 = h.percentile_ns(0.99);
        assert!((512..2_048).contains(&p50), "p50 {p50}");
        assert!((524_288..2_097_152).contains(&p99), "p99 {p99}");
        assert!(h.percentile_ms(0.99) > h.percentile_ms(0.50));
        // Duration-based recording lands in the same buckets.
        h.record(Duration::from_nanos(1_500));
        assert_eq!(h.count(), 101);
    }
}
