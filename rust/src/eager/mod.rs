//! The eager (imperative) executor — the paper's baseline execution model.
//!
//! Every DL op is dispatched individually: look up (or compile) the single-op
//! executable, launch it on PJRT, keep the result device-resident. This
//! mirrors TF-eager/PyTorch dispatch: correctness-identical to symbolic
//! execution but with per-op launch overhead and zero cross-op fusion, which
//! is exactly the gap Terra's co-execution closes.

use crate::error::Result;
use crate::ops::OpDef;
use crate::runtime::{ArtifactStore, Client, ExecCache, RtValue};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

pub struct EagerExecutor {
    client: Client,
    cache: Arc<ExecCache>,
    artifacts: Arc<ArtifactStore>,
    /// Shim backend resolved once at construction: the executable cache is
    /// backend-keyed, and reading `XLA_SHIM_BACKEND` per dispatch would put
    /// an env lookup + allocation on the measured eager hot path. The env
    /// var only flips between engine runs, and each run builds a fresh
    /// executor.
    backend: xla::ShimBackend,
    dispatches: AtomicU64,
    dispatch_nanos: AtomicU64,
}

impl EagerExecutor {
    pub fn new(client: Client, artifacts: Arc<ArtifactStore>) -> Self {
        EagerExecutor {
            client,
            cache: ExecCache::global().clone(),
            artifacts,
            backend: xla::active_backend(),
            dispatches: AtomicU64::new(0),
            dispatch_nanos: AtomicU64::new(0),
        }
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    pub fn artifacts(&self) -> &ArtifactStore {
        &self.artifacts
    }

    /// Execute one op. `inputs` may be host or device values; outputs stay on
    /// device (the common case for chained eager ops).
    pub fn execute(&self, def: &OpDef, inputs: &[RtValue]) -> Result<Vec<RtValue>> {
        let t0 = Instant::now();
        let exe = match &def.kind {
            crate::ops::OpKind::ArtifactCall { name, .. } => {
                self.artifacts.executable(&self.client, name)?
            }
            _ => self.cache.get_or_compile_op_for(self.backend, &self.client, def)?,
        };
        let out = exe.run(&self.client, inputs)?;
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.dispatch_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// (dispatch count, cumulative dispatch time in ns, cache hits, misses)
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        (
            self.dispatches.load(Ordering::Relaxed),
            self.dispatch_nanos.load(Ordering::Relaxed),
            self.cache.hits(),
            self.cache.misses(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::OpKind;
    use crate::tensor::{HostTensor, TensorType};

    fn executor() -> EagerExecutor {
        // Tests run without artifacts on disk; use an empty store.
        let dir = std::env::temp_dir().join(format!("terra_eager_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        EagerExecutor::new(Client::global().clone(), store)
    }

    #[test]
    fn chained_ops_stay_on_device() {
        let ex = executor();
        let x = HostTensor::f32(vec![4], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        let relu = OpDef::new(OpKind::Relu, vec![TensorType::f32(&[4])]);
        let y = ex.execute(&relu, &[RtValue::Host(x)]).unwrap().remove(0);
        assert!(matches!(y, RtValue::Dev(_)));
        let neg = OpDef::new(OpKind::Neg, vec![TensorType::f32(&[4])]);
        let z = ex.execute(&neg, &[y]).unwrap().remove(0);
        assert_eq!(z.to_host().unwrap().as_f32().unwrap(), &[-1.0, 0.0, -3.0, 0.0]);
        let (dispatches, _, hits, misses) = ex.stats();
        assert_eq!(dispatches, 2);
        // Cache counters are process-global (see ExecCache::global).
        assert!(hits + misses >= 2);
    }

    #[test]
    fn matmul_correctness() {
        let ex = executor();
        let a = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = HostTensor::f32(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let mm = OpDef::new(
            OpKind::MatMul,
            vec![TensorType::f32(&[2, 2]), TensorType::f32(&[2, 2])],
        );
        let y = ex
            .execute(&mm, &[RtValue::Host(a), RtValue::Host(b)])
            .unwrap()
            .remove(0);
        assert_eq!(y.to_host().unwrap().as_f32().unwrap(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn rng_shapes() {
        let ex = executor();
        let rng = OpDef::new(OpKind::RngUniform { shape: vec![8] }, vec![]);
        let y = ex.execute(&rng, &[]).unwrap().remove(0);
        let h = y.to_host().unwrap();
        assert_eq!(h.shape().dims(), &[8]);
        assert!(h.as_f32().unwrap().iter().all(|&v| (0.0..1.0).contains(&v)));
    }
}
