//! Deterministic fault injection for the symbolic side (`TERRA_FAULTS`).
//!
//! The fault-isolation contract — any symbolic-side panic, error or hang
//! degrades to imperative execution, never a process abort — is only
//! trustworthy if it is *exercised*. This module parses a fault schedule
//! from the environment and exposes cheap check hooks the engine and the
//! GraphRunner consult at the four symbolic choke points:
//!
//! | site           | hook location                                        |
//! |----------------|------------------------------------------------------|
//! | `compile`      | `Engine::build_plan` (optimizer + plangen + compile) |
//! | `segment_exec` | `GraphRunner::run_iteration`, before the step loop   |
//! | `worker`       | vendored shim worker pool, per claimed chunk         |
//! | `mailbox`      | `GraphRunner::run_iteration`, before a fetch `put`   |
//!
//! Schedule grammar (rules separated by `;`):
//!
//! ```text
//! TERRA_FAULTS = rule (';' rule)*
//! rule         = site ':' kind [':' trigger (',' trigger)*]
//! site         = 'compile' | 'segment_exec' | 'worker' | 'mailbox'
//! kind         = 'panic' | 'error' | 'hang' | '*'        (* = panic)
//! trigger      = 'iter=' N | 'chunk=' N | 'every=' N | 'p=' F
//! ```
//!
//! e.g. `compile:*:iter=2;segment_exec:panic:iter=5;worker:panic:chunk=3`.
//!
//! Occurrence counting is 1-based per site: `iter=N` fires on the Nth check
//! at that site over the plan's lifetime (once), `every=N` on every Nth, no
//! trigger on every check. `p=F` thins whatever the trigger selected with a
//! per-rule splitmix64 stream seeded from `TERRA_FAULTS_SEED` (default 0) —
//! seeded determinism: the same schedule, seed and program fault at the
//! same points on every run. `chunk=N` is exclusive to the `worker` site:
//! the shim's pool hook (armed by the GraphRunner around each segment
//! execution) panics the worker closure claiming the Nth chunk, exercising
//! the pool's own panic containment rather than a hook above it.
//!
//! `hang` is only meaningful where a watchdog can observe it
//! (`segment_exec`, `mailbox`); the runner implements it as a cancellable
//! sleep so an engine-side cancel (watchdog or shutdown) still reclaims the
//! thread. `hang` on `compile` is rejected at parse time: plan build runs on
//! the engine thread, where a hang would stall the program with no one left
//! to cancel it.
//!
//! Malformed schedules are a loud [`TerraError::Config`] naming
//! `TERRA_FAULTS` (same strictness contract as every other knob in
//! `config/env.rs`); absence means no injection and zero overhead beyond an
//! `Option` check.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{Result, TerraError};

/// Injection sites (indices into the per-site occurrence counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    Compile,
    SegmentExec,
    Worker,
    Mailbox,
}

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::Compile => 0,
            FaultSite::SegmentExec => 1,
            FaultSite::Worker => 2,
            FaultSite::Mailbox => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Compile => "compile",
            FaultSite::SegmentExec => "segment_exec",
            FaultSite::Worker => "worker",
            FaultSite::Mailbox => "mailbox",
        }
    }
}

/// What an armed hook does when its rule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the hook (exercises the `catch_unwind` boundaries).
    Panic,
    /// Return a structured fault error (exercises the error routing).
    Error,
    /// Block until cancelled (exercises the watchdog).
    Hang,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Trigger {
    /// Fire on exactly the Nth occurrence (1-based), once.
    Nth(u64),
    /// Fire on every Nth occurrence.
    Every(u64),
    /// Fire on every occurrence (subject to `p`, if any).
    Always,
}

#[derive(Debug)]
struct FaultRule {
    site: FaultSite,
    kind: FaultKind,
    trigger: Trigger,
    /// `chunk=N` payload (worker rules only).
    chunk: Option<u64>,
    /// Probabilistic thinning: `(p, splitmix64 state)`.
    prob: Option<(f64, AtomicU64)>,
}

impl FaultRule {
    /// Does this rule fire at the given 1-based occurrence?
    fn fires(&self, occurrence: u64) -> bool {
        let triggered = match self.trigger {
            Trigger::Nth(n) => occurrence == n,
            Trigger::Every(n) => occurrence % n == 0,
            Trigger::Always => true,
        };
        if !triggered {
            return false;
        }
        match &self.prob {
            None => true,
            Some((p, state)) => {
                let draw = state
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
                        Some(s.wrapping_add(0x9E37_79B9_7F4A_7C15))
                    })
                    .map(|prev| splitmix64_mix(prev.wrapping_add(0x9E37_79B9_7F4A_7C15)))
                    .unwrap_or(0);
                // Map the draw onto [0, 1): 53 bits of mantissa, like a
                // standard uniform double construction.
                let unit = (draw >> 11) as f64 / (1u64 << 53) as f64;
                unit < *p
            }
        }
    }
}

fn splitmix64_mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A parsed, armed fault schedule. Shared (`Arc`) between the engine and
/// its GraphRunner threads; all state is atomic, so checks are lock-free.
#[derive(Debug)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    /// Per-site occurrence counters (1-based after increment).
    counts: [AtomicU64; 4],
    /// Faults this plan has injected (worker-chunk faults are folded in by
    /// the GraphRunner via [`note_injected`](FaultPlan::note_injected)).
    injected: AtomicU64,
}

impl FaultPlan {
    /// Parse a `TERRA_FAULTS` schedule. `seed` drives the `p=` streams.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let bad = |detail: String| TerraError::Config(format!("TERRA_FAULTS: {detail}"));
        let mut rules = Vec::new();
        for (idx, rule_str) in
            spec.split(';').map(str::trim).filter(|r| !r.is_empty()).enumerate()
        {
            let mut parts = rule_str.splitn(3, ':');
            let site = match parts.next().unwrap_or("").trim() {
                "compile" => FaultSite::Compile,
                "segment_exec" => FaultSite::SegmentExec,
                "worker" => FaultSite::Worker,
                "mailbox" => FaultSite::Mailbox,
                other => {
                    return Err(bad(format!(
                        "unknown site '{other}' in '{rule_str}' \
                         (expected compile | segment_exec | worker | mailbox)"
                    )))
                }
            };
            let kind = match parts.next().map(str::trim) {
                Some("panic") | Some("*") => FaultKind::Panic,
                Some("error") => FaultKind::Error,
                Some("hang") => FaultKind::Hang,
                Some(other) => {
                    return Err(bad(format!(
                        "unknown kind '{other}' in '{rule_str}' \
                         (expected panic | error | hang | *)"
                    )))
                }
                None => {
                    return Err(bad(format!("rule '{rule_str}' is missing its kind")))
                }
            };
            if kind == FaultKind::Hang && site == FaultSite::Compile {
                return Err(bad(format!(
                    "'{rule_str}': hang is not injectable at compile (plan \
                     build runs on the engine thread, nothing could cancel it)"
                )));
            }
            if kind == FaultKind::Hang && site == FaultSite::Worker {
                return Err(bad(format!(
                    "'{rule_str}': worker faults are chunk panics only"
                )));
            }
            let mut trigger = Trigger::Always;
            let mut chunk = None;
            let mut prob = None;
            if let Some(trigger_str) = parts.next() {
                for t in trigger_str.split(',').map(str::trim).filter(|t| !t.is_empty()) {
                    let (key, value) = t.split_once('=').ok_or_else(|| {
                        bad(format!(
                            "trigger '{t}' in '{rule_str}' is not key=value"
                        ))
                    })?;
                    let num = |v: &str| -> Result<u64> {
                        v.trim().parse::<u64>().map_err(|_| {
                            bad(format!("trigger '{t}' in '{rule_str}': '{v}' is not a number"))
                        })
                    };
                    match key.trim() {
                        "iter" => {
                            let n = num(value)?;
                            if n == 0 {
                                return Err(bad(format!(
                                    "trigger '{t}' in '{rule_str}': occurrences are 1-based"
                                )));
                            }
                            trigger = Trigger::Nth(n);
                        }
                        "every" => {
                            let n = num(value)?;
                            if n == 0 {
                                return Err(bad(format!(
                                    "trigger '{t}' in '{rule_str}': every=0 is meaningless"
                                )));
                            }
                            trigger = Trigger::Every(n);
                        }
                        "chunk" => chunk = Some(num(value)?),
                        "p" => {
                            let p: f64 = value.trim().parse().map_err(|_| {
                                bad(format!(
                                    "trigger '{t}' in '{rule_str}': '{value}' is not a probability"
                                ))
                            })?;
                            if !(0.0..=1.0).contains(&p) {
                                return Err(bad(format!(
                                    "trigger '{t}' in '{rule_str}': p must be in [0, 1]"
                                )));
                            }
                            // Per-rule stream: the seed offset by the rule
                            // index keeps rules independent but reproducible.
                            let state = splitmix64_mix(seed ^ (idx as u64).wrapping_mul(0xA5A5));
                            prob = Some((p, AtomicU64::new(state)));
                        }
                        other => {
                            return Err(bad(format!(
                                "unknown trigger '{other}' in '{rule_str}' \
                                 (expected iter= | chunk= | every= | p=)"
                            )))
                        }
                    }
                }
            }
            if (site == FaultSite::Worker) != chunk.is_some() {
                return Err(bad(format!(
                    "'{rule_str}': chunk= is required for worker rules and \
                     invalid everywhere else"
                )));
            }
            rules.push(FaultRule { site, kind, trigger, chunk, prob });
        }
        if rules.is_empty() {
            return Err(bad("empty schedule (unset the variable to disable injection)".into()));
        }
        Ok(FaultPlan {
            rules,
            counts: Default::default(),
            injected: AtomicU64::new(0),
        })
    }

    /// Build the process fault plan from `TERRA_FAULTS` /
    /// `TERRA_FAULTS_SEED`: `Ok(None)` when unset, strict errors on junk.
    pub fn from_env() -> Result<Option<Arc<FaultPlan>>> {
        let spec = match std::env::var("TERRA_FAULTS") {
            Ok(v) => v,
            Err(std::env::VarError::NotPresent) => return Ok(None),
            Err(e) => return Err(TerraError::Config(format!("TERRA_FAULTS: {e}"))),
        };
        let seed = crate::config::env::parse_env::<u64>("TERRA_FAULTS_SEED")?.unwrap_or(0);
        FaultPlan::parse(&spec, seed).map(|p| Some(Arc::new(p)))
    }

    /// Record one occurrence at `site` and report the fault to inject, if
    /// any. First matching rule wins. `Worker` occurrences are counted by
    /// the shim's own chunk hook, never through here.
    pub fn check(&self, site: FaultSite) -> Option<FaultKind> {
        debug_assert_ne!(site, FaultSite::Worker, "worker faults go through the shim hook");
        let occurrence = self.counts[site.index()].fetch_add(1, Ordering::Relaxed) + 1;
        for rule in self.rules.iter().filter(|r| r.site == site) {
            if rule.fires(occurrence) {
                self.injected.fetch_add(1, Ordering::Relaxed);
                let kind_code = match rule.kind {
                    FaultKind::Panic => 0,
                    FaultKind::Error => 1,
                    FaultKind::Hang => 2,
                };
                crate::obs::instant(
                    crate::obs::Track::Engine,
                    crate::obs::InstantKind::FaultInjected,
                    0,
                    site.index() as u64,
                    kind_code,
                );
                return Some(rule.kind);
            }
        }
        None
    }

    /// The chunk ordinal at which the shim's worker hook should panic for
    /// the *next* segment execution, if a worker rule fires for it. Each
    /// call counts one `worker` occurrence (the GraphRunner calls this once
    /// per segment execution when arming `xla::set_chunk_fault`), so
    /// `iter=`/`every=`/`p=` triggers select *which* executions are armed.
    /// The injected total is counted by the shim hook itself and folded in
    /// via [`note_injected`](FaultPlan::note_injected), not here.
    pub fn worker_chunk_fault(&self) -> Option<u64> {
        if !self.rules.iter().any(|r| r.site == FaultSite::Worker) {
            return None;
        }
        let occurrence = self.counts[FaultSite::Worker.index()].fetch_add(1, Ordering::Relaxed) + 1;
        self.rules
            .iter()
            .filter(|r| r.site == FaultSite::Worker)
            .find(|r| r.fires(occurrence))
            .and_then(|r| r.chunk)
    }

    /// Faults injected so far (shim-side chunk faults included once the
    /// GraphRunner folds them in).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Fold externally counted injections (the shim's chunk faults) into
    /// this plan's total.
    pub fn note_injected(&self, n: u64) {
        if n > 0 {
            self.injected.fetch_add(n, Ordering::Relaxed);
            // Worker-chunk faults are observed after the fact (the shim hook
            // counted them); one instant per folded fault keeps the trace
            // honest about the total.
            for _ in 0..n {
                crate::obs::instant(
                    crate::obs::Track::Engine,
                    crate::obs::InstantKind::FaultInjected,
                    0,
                    FaultSite::Worker.index() as u64,
                    0,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let plan =
            FaultPlan::parse("compile:*:iter=2;segment_exec:panic:iter=5;worker:panic:chunk=3", 0)
                .unwrap();
        assert_eq!(plan.rules.len(), 3);
        assert_eq!(plan.worker_chunk_fault(), Some(3));
        // compile fires on its 2nd occurrence only.
        assert_eq!(plan.check(FaultSite::Compile), None);
        assert_eq!(plan.check(FaultSite::Compile), Some(FaultKind::Panic));
        assert_eq!(plan.check(FaultSite::Compile), None);
        // segment_exec fires on its 5th occurrence only.
        for _ in 0..4 {
            assert_eq!(plan.check(FaultSite::SegmentExec), None);
        }
        assert_eq!(plan.check(FaultSite::SegmentExec), Some(FaultKind::Panic));
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn every_and_untriggered_rules() {
        let plan = FaultPlan::parse("mailbox:error:every=3", 0).unwrap();
        let fired: Vec<bool> =
            (0..9).map(|_| plan.check(FaultSite::Mailbox).is_some()).collect();
        assert_eq!(fired, [false, false, true, false, false, true, false, false, true]);
        let always = FaultPlan::parse("segment_exec:error", 0).unwrap();
        assert_eq!(always.check(FaultSite::SegmentExec), Some(FaultKind::Error));
        assert_eq!(always.check(FaultSite::SegmentExec), Some(FaultKind::Error));
    }

    #[test]
    fn probabilistic_rules_are_seed_deterministic() {
        let run = |seed: u64| -> Vec<bool> {
            let plan = FaultPlan::parse("segment_exec:error:p=0.5", seed).unwrap();
            (0..64).map(|_| plan.check(FaultSite::SegmentExec).is_some()).collect()
        };
        assert_eq!(run(7), run(7), "same seed, same schedule");
        assert_ne!(run(7), run(8), "different seeds diverge");
        let fired = run(7).iter().filter(|f| **f).count();
        assert!(fired > 8 && fired < 56, "p=0.5 should fire roughly half: {fired}");
        // p=0 never fires, p=1 always fires.
        let never = FaultPlan::parse("segment_exec:error:p=0", 1).unwrap();
        assert!((0..32).all(|_| never.check(FaultSite::SegmentExec).is_none()));
        let always = FaultPlan::parse("segment_exec:error:p=1", 1).unwrap();
        assert!((0..32).all(|_| always.check(FaultSite::SegmentExec).is_some()));
    }

    #[test]
    fn junk_schedules_are_loud_errors_naming_the_knob() {
        for bad in [
            "gpu:panic",                     // unknown site
            "compile:explode",               // unknown kind
            "compile",                       // missing kind
            "compile:hang",                  // hang not injectable at compile
            "worker:hang:chunk=1",           // worker faults are panics
            "compile:panic:iter",            // trigger not key=value
            "compile:panic:iter=abc",        // non-numeric
            "compile:panic:when=3",          // unknown trigger
            "compile:panic:every=0",         // meaningless period
            "segment_exec:error:p=1.5",      // probability out of range
            "segment_exec:error:p=x",        // probability junk
            "worker:panic",                  // worker requires chunk=
            "compile:panic:chunk=3",         // chunk= outside worker
            "",                              // empty schedule
            " ; ",                           // whitespace-only schedule
        ] {
            let e = FaultPlan::parse(bad, 0).unwrap_err();
            let msg = e.to_string();
            assert!(msg.contains("TERRA_FAULTS"), "error must name the knob: {msg} (for {bad:?})");
        }
    }

    #[test]
    fn first_matching_rule_wins_and_sites_are_independent() {
        let plan =
            FaultPlan::parse("segment_exec:error:iter=1;segment_exec:panic:iter=1", 0).unwrap();
        assert_eq!(plan.check(FaultSite::SegmentExec), Some(FaultKind::Error));
        // A compile check does not advance the segment_exec counter.
        let plan2 = FaultPlan::parse("segment_exec:hang:iter=2;mailbox:panic:iter=1", 0).unwrap();
        assert_eq!(plan2.check(FaultSite::SegmentExec), None);
        assert_eq!(plan2.check(FaultSite::Mailbox), Some(FaultKind::Panic));
        assert_eq!(plan2.check(FaultSite::SegmentExec), Some(FaultKind::Hang));
    }
}
