//! nn-layer correctness against naive host oracles: the composite conv
//! (im2col), pooling, layer norm and the attention layer are validated
//! against straightforward host-side reimplementations.

use std::collections::BTreeMap;
use std::sync::Arc;
use terra::api::{Backend, EagerBackend, Session, VarStore};
use terra::config::ExecMode;
use terra::data::Rng;
use terra::eager::EagerExecutor;
use terra::nn::{avg_pool2, global_avg_pool, max_pool2, Conv2d, LayerNorm, MultiHeadAttention, Padding};
use terra::programs::{TrainMlp, TrainOptim};
use terra::runner::Engine;
use terra::runtime::{ArtifactStore, Client};
use terra::tensor::HostTensor;

fn session() -> Session {
    let dir = std::env::temp_dir().join("terra_nn_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let client = Client::global().clone();
    let vars = Arc::new(VarStore::new(client.clone()));
    let exec = Arc::new(EagerExecutor::new(client, store.clone()));
    let backend: Box<dyn Backend> = Box::new(EagerBackend::new(exec, vars.clone()));
    Session::new(backend, store, vars)
}

fn close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() <= tol * y.abs().max(1.0), "{x} vs {y}");
    }
}

/// Naive NCHW conv with 'same' zero padding, stride 1, kernel k, plus bias.
/// Weight layout matches Conv2d: [(di*k+dj)*C + c, oc].
#[allow(clippy::too_many_arguments)]
fn conv_oracle(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    c_in: usize,
    h: usize,
    wdt: usize,
    c_out: usize,
    k: usize,
) -> Vec<f32> {
    let p = k / 2;
    let mut out = vec![0f32; b * c_out * h * wdt];
    for bi in 0..b {
        for oc in 0..c_out {
            for oy in 0..h {
                for ox in 0..wdt {
                    let mut acc = bias[oc];
                    for di in 0..k {
                        for dj in 0..k {
                            for ci in 0..c_in {
                                let iy = oy as isize + di as isize - p as isize;
                                let ix = ox as isize + dj as isize - p as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wdt as isize {
                                    continue;
                                }
                                let xv = x[((bi * c_in + ci) * h + iy as usize) * wdt + ix as usize];
                                let wv = w[((di * k + dj) * c_in + ci) * c_out + oc];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[((bi * c_out + oc) * h + oy) * wdt + ox] = acc;
                }
            }
        }
    }
    out
}

#[test]
fn conv2d_matches_naive_convolution() {
    let (b, c_in, h, w, c_out, k) = (2, 3, 4, 4, 5, 3);
    let s = session();
    let mut rng = Rng::new(11);
    let conv = Conv2d::new(&s, "c", c_in, c_out, k, Padding::Same, &mut rng).unwrap();
    s.begin_step(0).unwrap();
    let x_host: Vec<f32> = (0..b * c_in * h * w).map(|i| ((i as f32) * 0.37).sin()).collect();
    let x = s.feed(HostTensor::f32(vec![b, c_in, h, w], x_host.clone()).unwrap()).unwrap();
    let y = conv.forward(&x).unwrap().value().unwrap();
    let w_host = conv.w.snapshot().unwrap();
    let b_host = conv.b.snapshot().unwrap();
    let want = conv_oracle(
        &x_host,
        w_host.as_f32().unwrap(),
        b_host.as_f32().unwrap(),
        b,
        c_in,
        h,
        w,
        c_out,
        k,
    );
    close(y.as_f32().unwrap(), &want, 1e-4);
}

#[test]
fn pooling_matches_oracle() {
    let s = session();
    s.begin_step(0).unwrap();
    let x_host: Vec<f32> = (0..1 * 2 * 4 * 4).map(|i| (i as f32 * 1.3).cos()).collect();
    let x = s.feed(HostTensor::f32(vec![1, 2, 4, 4], x_host.clone()).unwrap()).unwrap();
    let maxed = max_pool2(&x).unwrap().value().unwrap();
    let avged = avg_pool2(&x).unwrap().value().unwrap();
    let gap = global_avg_pool(&x).unwrap().value().unwrap();
    let mut want_max = Vec::new();
    let mut want_avg = Vec::new();
    for c in 0..2 {
        for oy in 0..2 {
            for ox in 0..2 {
                let mut m = f32::MIN;
                let mut a = 0.0;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let v = x_host[(c * 4 + oy * 2 + dy) * 4 + ox * 2 + dx];
                        m = m.max(v);
                        a += v;
                    }
                }
                want_max.push(m);
                want_avg.push(a / 4.0);
            }
        }
    }
    close(maxed.as_f32().unwrap(), &want_max, 1e-6);
    close(avged.as_f32().unwrap(), &want_avg, 1e-6);
    for c in 0..2 {
        let mean: f32 = x_host[c * 16..(c + 1) * 16].iter().sum::<f32>() / 16.0;
        assert!((gap.as_f32().unwrap()[c] - mean).abs() < 1e-5);
    }
}

#[test]
fn layernorm_normalizes_rows() {
    let s = session();
    let ln = LayerNorm::new(&s, "ln", 8).unwrap();
    s.begin_step(0).unwrap();
    let x_host: Vec<f32> = (0..4 * 8).map(|i| (i as f32 * 0.71).sin() * 3.0 + 1.0).collect();
    let x = s.feed(HostTensor::f32(vec![4, 8], x_host).unwrap()).unwrap();
    let y = ln.forward(&x).unwrap().value().unwrap();
    let yv = y.as_f32().unwrap();
    for r in 0..4 {
        let row = &yv[r * 8..(r + 1) * 8];
        let mean: f32 = row.iter().sum::<f32>() / 8.0;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 8.0;
        assert!(mean.abs() < 1e-4, "row {r} mean {mean}");
        assert!((var - 1.0).abs() < 1e-2, "row {r} var {var}");
    }
}

#[test]
fn attention_rows_are_convex_combinations_of_values() {
    // With V = all-ones, any softmax mixture returns exactly ones after Wo if
    // Wo is identity-free; instead check the sdpa core through the layer by
    // using value vectors with a known invariant: sum over features of
    // softmax-mixed rows equals mixture of row sums.
    let s = session();
    let mut rng = Rng::new(3);
    let mha = MultiHeadAttention::new(&s, "mha", 8, 2, false, None, &mut rng).unwrap();
    s.begin_step(0).unwrap();
    let x = s
        .feed(HostTensor::f32(vec![1, 4, 8], (0..32).map(|i| (i as f32 * 0.2).sin()).collect()).unwrap())
        .unwrap();
    let y = mha.forward(&x, false).unwrap();
    assert_eq!(y.shape_dims(), &[1, 4, 8]);
    let v = y.value().unwrap();
    assert!(v.as_f32().unwrap().iter().all(|f| f.is_finite()));
}

/// Run the Adam train loop end to end and return the per-step loss bits plus
/// every committed variable buffer (params + adam.m*/adam.v*/adam.t) as bits.
/// Fusion off / opt 0 so every plan node compiles to the same single-op shim
/// kernel the eager executor uses — bitwise comparison is valid.
fn adam_train(mode: ExecMode, fused: bool, steps: u64) -> (Vec<u32>, BTreeMap<String, Vec<u32>>) {
    let dir = std::env::temp_dir().join("terra_nn_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    let mut engine =
        Engine::with_opt_level(mode, dir.to_string_lossy().as_ref(), false, 0).unwrap();
    engine.loss_every = 1;
    let mut prog = TrainMlp::new(TrainOptim::Adam, fused);
    let report = engine.run(&mut prog, steps, 0).unwrap();
    let losses = report.losses.iter().map(|(_, l)| l.to_bits()).collect();
    let mut bufs = BTreeMap::new();
    for id in engine.vars().ids() {
        let name = engine.vars().meta(id).unwrap().name;
        let host = engine.vars().host(id).unwrap();
        bufs.insert(name, host.as_f32().unwrap().iter().map(|f| f.to_bits()).collect());
    }
    (losses, bufs)
}

/// ISSUE satellite: the traced-fused Adam update must be bit-exact against
/// the eager unfused oracle over ≥50 steps — losses AND moment buffers — on
/// both shim backends (bytecode default + interpreter).
#[test]
fn traced_fused_adam_matches_eager_oracle_bitwise_on_both_backends() {
    let steps = 50;
    let (oracle_losses, oracle_bufs) = adam_train(ExecMode::Eager, false, steps);
    assert_eq!(oracle_losses.len() as u64, steps);
    assert!(oracle_bufs.keys().any(|k| k.starts_with("adam.m")), "{oracle_bufs:?}");
    assert!(oracle_bufs.keys().any(|k| k.starts_with("adam.v")), "{oracle_bufs:?}");

    // Default backend (bytecode unless the environment overrides it).
    let (losses, bufs) = adam_train(ExecMode::Terra, true, steps);
    assert_eq!(oracle_losses, losses, "fused losses must match eager Adam bit for bit");
    assert_eq!(oracle_bufs, bufs, "fused params + moments must match eager Adam bit for bit");

    // Interpreter backend. Process-global knob: save/restore around the run
    // (backends are bit-identical by contract, and segment caches key on the
    // active backend, so concurrent tests in this binary are unaffected).
    let prev = std::env::var("XLA_SHIM_BACKEND").ok();
    std::env::set_var("XLA_SHIM_BACKEND", "interp");
    let result = std::panic::catch_unwind(|| {
        let (losses, bufs) = adam_train(ExecMode::Terra, true, steps);
        assert_eq!(oracle_losses, losses, "interp: fused losses must match eager Adam");
        assert_eq!(oracle_bufs, bufs, "interp: fused params + moments must match eager Adam");
    });
    match prev {
        Some(v) => std::env::set_var("XLA_SHIM_BACKEND", v),
        None => std::env::remove_var("XLA_SHIM_BACKEND"),
    }
    if let Err(p) = result {
        std::panic::resume_unwind(p);
    }
}

#[test]
fn causal_attention_ignores_future_tokens() {
    // Changing token t's embedding must not affect outputs at positions < t
    // under a causal mask.
    let s = session();
    let mut rng = Rng::new(5);
    let mha = MultiHeadAttention::new(&s, "mha", 8, 2, false, None, &mut rng).unwrap();
    s.begin_step(0).unwrap();
    let base: Vec<f32> = (0..4 * 8).map(|i| (i as f32 * 0.13).cos()).collect();
    let mut perturbed = base.clone();
    for v in &mut perturbed[3 * 8..4 * 8] {
        *v += 5.0; // change only the last token
    }
    let x1 = s.feed(HostTensor::f32(vec![1, 4, 8], base).unwrap()).unwrap();
    let y1 = mha.forward(&x1, true).unwrap().value().unwrap();
    let x2 = s.feed(HostTensor::f32(vec![1, 4, 8], perturbed).unwrap()).unwrap();
    let y2 = mha.forward(&x2, true).unwrap().value().unwrap();
    let (a, b) = (y1.as_f32().unwrap(), y2.as_f32().unwrap());
    close(&a[..3 * 8], &b[..3 * 8], 1e-5); // positions 0..2 unchanged
    // ...and the perturbed position itself must change
    let diff: f32 = a[3 * 8..].iter().zip(&b[3 * 8..]).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-3);
}
