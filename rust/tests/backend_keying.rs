//! Cross-backend cache-staleness regression (ISSUE 4 bugfix): flipping
//! `XLA_SHIM_BACKEND` between engine runs inside one process — as the
//! differential tests and the interp CI job do — must invalidate both the
//! speculation plan cache and the segment executable cache. Before the fix,
//! `PlanKey` ignored the backend and `segment_key` did too, so a process
//! that switched to the interpreter could silently reuse executables
//! compiled for the bytecode backend.
//!
//! Kept in its own test binary: it mutates process-global environment
//! variables, and every other `#[test]` in the same binary would run
//! concurrently under the flipped backend.

use std::env;
use terra::config::ExecMode;
use terra::programs::TinyLinear;
use terra::runner::{Engine, EngineStats};
use terra::speculate::{ReentryPolicy, SpeculateConfig};

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_backend_keying_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        std::fs::write(manifest, r#"{"artifacts": []}"#).unwrap();
    }
    dir.to_string_lossy().into_owned()
}

fn run(spec: SpeculateConfig) -> (EngineStats, f32) {
    let dir = artifacts_dir();
    let mut engine = Engine::with_speculate(ExecMode::Terra, &dir, true, 2, spec).unwrap();
    let mut prog = TinyLinear::new(5);
    let report = engine.run(&mut prog, 23, 0).unwrap();
    let w = prog.w.as_ref().unwrap().id();
    let w0 = engine.vars().host(w).unwrap().as_f32().unwrap()[0];
    (report.stats, w0)
}

#[test]
fn flipping_shim_backend_invalidates_cached_plans_and_segments() {
    let spec = SpeculateConfig {
        plan_cache: true,
        policy: ReentryPolicy::Eager,
        split_hot_sites: false,
    };

    // Run 1 under the default bytecode backend.
    env::remove_var("XLA_SHIM_BACKEND");
    let (s1, w1) = run(spec);
    assert!(s1.enter_coexec >= 1, "{s1:?}");
    assert!(s1.plan_cache_misses >= 1, "first run must populate the cache: {s1:?}");

    // Run 2 under the interpreter. Same program, same graph signatures —
    // with backend-blind keys the plan cache would hand back executables
    // compiled for the bytecode backend and the interpreter would never run.
    let interp_before = xla::shim_totals().interp_executions;
    env::set_var("XLA_SHIM_BACKEND", "interp");
    let (s2, w2) = run(spec);
    assert_eq!(
        s2.plan_cache_hits, 0,
        "a plan compiled under the bytecode backend must not serve the interp backend: {s2:?}"
    );
    assert!(s2.plan_cache_misses >= 1, "{s2:?}");
    assert!(
        s2.segments_compiled >= 1,
        "segments must recompile for the interp backend instead of reusing bytecode \
         executables: {s2:?}"
    );
    assert!(
        xla::shim_totals().interp_executions > interp_before,
        "co-execution under XLA_SHIM_BACKEND=interp must actually run on the interpreter"
    );
    // The backends are bit-identical by contract (shim_differential.rs), so
    // the flip must not change numerics either.
    assert!((w1 - w2).abs() <= 1e-6, "backend flip changed results: {w1} vs {w2}");

    // Run 3 under the same (interp) backend: reuse is still allowed.
    let (s3, _) = run(spec);
    assert!(s3.plan_cache_hits >= 1, "same-backend plans must still hit: {s3:?}");

    env::remove_var("XLA_SHIM_BACKEND");
}
