//! Systematic op-semantics suite: every `OpKind` is eager-executed against a
//! hand-written host oracle, and every differentiable op's tape gradient is
//! checked against central finite differences. This is the numeric bedrock
//! under the whole stack — eager, fused segments and artifacts all lower
//! through the same `ops::lowering`.

use std::sync::Arc;
use terra::api::{Backend, EagerBackend, Session, VarStore};
use terra::eager::EagerExecutor;
use terra::ops::OpKind;
use terra::runtime::{ArtifactStore, Client};
use terra::tape::Tape;
use terra::tensor::{DType, HostTensor};

fn session() -> Session {
    let dir = std::env::temp_dir().join("terra_opsem_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let client = Client::global().clone();
    let vars = Arc::new(VarStore::new(client.clone()));
    let exec = Arc::new(EagerExecutor::new(client, store.clone()));
    let backend: Box<dyn Backend> = Box::new(EagerBackend::new(exec, vars.clone()));
    Session::new(backend, store, vars)
}

fn t(sess: &Session, dims: &[usize], data: Vec<f32>) -> terra::api::Tensor {
    sess.feed(HostTensor::f32(dims.to_vec(), data).unwrap()).unwrap()
}

fn assert_vals(got: &HostTensor, want: &[f32]) {
    let g = got.as_f32().unwrap();
    assert_eq!(g.len(), want.len(), "length mismatch: {g:?} vs {want:?}");
    for (a, b) in g.iter().zip(want) {
        assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{g:?} vs {want:?}");
    }
}

// ---------------------------------------------------------------------------
// forward semantics
// ---------------------------------------------------------------------------

#[test]
fn elementwise_binary_ops() {
    let s = session();
    s.begin_step(0).unwrap();
    let a = t(&s, &[4], vec![1.0, -2.0, 3.0, 0.5]);
    let b = t(&s, &[4], vec![2.0, 2.0, -1.0, 0.25]);
    assert_vals(&a.add(&b).unwrap().value().unwrap(), &[3.0, 0.0, 2.0, 0.75]);
    assert_vals(&a.sub(&b).unwrap().value().unwrap(), &[-1.0, -4.0, 4.0, 0.25]);
    assert_vals(&a.mul(&b).unwrap().value().unwrap(), &[2.0, -4.0, -3.0, 0.125]);
    assert_vals(&a.div(&b).unwrap().value().unwrap(), &[0.5, -1.0, -3.0, 2.0]);
    assert_vals(&a.maximum(&b).unwrap().value().unwrap(), &[2.0, 2.0, 3.0, 0.5]);
    assert_vals(&a.minimum(&b).unwrap().value().unwrap(), &[1.0, -2.0, -1.0, 0.25]);
}

#[test]
fn comparison_ops_yield_i32() {
    let s = session();
    s.begin_step(0).unwrap();
    let a = t(&s, &[3], vec![1.0, 2.0, 3.0]);
    let b = t(&s, &[3], vec![2.0, 2.0, 2.0]);
    let table: Vec<(OpKind, Vec<i32>)> = vec![
        (OpKind::Greater, vec![0, 0, 1]),
        (OpKind::GreaterEqual, vec![0, 1, 1]),
        (OpKind::Less, vec![1, 0, 0]),
        (OpKind::LessEqual, vec![1, 1, 0]),
        (OpKind::Equal, vec![0, 1, 0]),
        (OpKind::NotEqual, vec![1, 0, 1]),
    ];
    for (kind, want) in table {
        let out = s.issue(kind.clone(), &[&a, &b]).unwrap().value().unwrap();
        assert_eq!(out.dtype(), DType::I32, "{kind:?}");
        assert_eq!(out.as_i32().unwrap(), want.as_slice(), "{kind:?}");
    }
}

#[test]
fn unary_ops() {
    let s = session();
    s.begin_step(0).unwrap();
    let x = t(&s, &[3], vec![0.25, 1.0, 4.0]);
    assert_vals(&x.sqrt().unwrap().value().unwrap(), &[0.5, 1.0, 2.0]);
    assert_vals(&x.rsqrt().unwrap().value().unwrap(), &[2.0, 1.0, 0.5]);
    assert_vals(&x.log().unwrap().value().unwrap(), &[0.25f32.ln(), 0.0, 4.0f32.ln()]);
    assert_vals(&x.exp().unwrap().value().unwrap(), &[0.25f32.exp(), 1.0f32.exp(), 4.0f32.exp()]);
    let y = t(&s, &[3], vec![-1.5, 0.0, 2.0]);
    assert_vals(&y.neg().unwrap().value().unwrap(), &[1.5, 0.0, -2.0]);
    assert_vals(&y.abs().unwrap().value().unwrap(), &[1.5, 0.0, 2.0]);
    assert_vals(&y.sign().unwrap().value().unwrap(), &[-1.0, 0.0, 1.0]);
    assert_vals(&y.relu().unwrap().value().unwrap(), &[0.0, 0.0, 2.0]);
    assert_vals(&y.tanh().unwrap().value().unwrap(), &[(-1.5f32).tanh(), 0.0, 2.0f32.tanh()]);
    assert_vals(
        &y.sigmoid().unwrap().value().unwrap(),
        &[1.0 / (1.0 + 1.5f32.exp()), 0.5, 1.0 / (1.0 + (-2.0f32).exp())],
    );
}

#[test]
fn select_mixes_by_condition() {
    let s = session();
    s.begin_step(0).unwrap();
    let c = s.feed(HostTensor::i32(vec![3], vec![1, 0, 1]).unwrap()).unwrap();
    let a = t(&s, &[3], vec![10.0, 20.0, 30.0]);
    let b = t(&s, &[3], vec![-1.0, -2.0, -3.0]);
    assert_vals(&c.select(&a, &b).unwrap().value().unwrap(), &[10.0, -2.0, 30.0]);
}

#[test]
fn matmul_2d_and_batched() {
    let s = session();
    s.begin_step(0).unwrap();
    let a = t(&s, &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    let b = t(&s, &[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
    assert_vals(&a.matmul(&b).unwrap().value().unwrap(), &[58.0, 64.0, 139.0, 154.0]);
    // batched [2,1,2] @ [2,2,1]
    let x = t(&s, &[2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let y = t(&s, &[2, 2, 1], vec![5.0, 6.0, 7.0, 8.0]);
    assert_vals(&x.matmul(&y).unwrap().value().unwrap(), &[17.0, 53.0]);
    // rank-3 @ rank-2 (collapse path)
    let w = t(&s, &[2, 1], vec![1.0, -1.0]);
    let z = t(&s, &[2, 2, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    assert_vals(&z.matmul(&w).unwrap().value().unwrap(), &[-1.0, -1.0, -1.0, -1.0]);
}

#[test]
fn shape_ops() {
    let s = session();
    s.begin_step(0).unwrap();
    let x = t(&s, &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    assert_vals(
        &x.transpose(&[1, 0]).unwrap().value().unwrap(),
        &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0],
    );
    assert_vals(&x.reshape(&[3, 2]).unwrap().value().unwrap(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    assert_vals(&x.slice(&[0, 1], &[2, 2]).unwrap().value().unwrap(), &[2.0, 3.0, 5.0, 6.0]);
    assert_vals(
        &x.pad(&[0, 1], &[0, 0]).unwrap().value().unwrap(),
        &[0.0, 1.0, 2.0, 3.0, 0.0, 4.0, 5.0, 6.0],
    );
    let row = t(&s, &[3], vec![1.0, 2.0, 3.0]);
    assert_vals(
        &row.broadcast_to(&[2, 3]).unwrap().value().unwrap(),
        &[1.0, 2.0, 3.0, 1.0, 2.0, 3.0],
    );
    let a = t(&s, &[1, 2], vec![1.0, 2.0]);
    let b = t(&s, &[1, 2], vec![3.0, 4.0]);
    assert_vals(&s.concat(&[&a, &b], 0).unwrap().value().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    assert_vals(&s.concat(&[&a, &b], 1).unwrap().value().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
}

#[test]
fn reductions_and_softmax() {
    let s = session();
    s.begin_step(0).unwrap();
    let x = t(&s, &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    assert_vals(&x.reduce_sum(&[1], false).unwrap().value().unwrap(), &[6.0, 15.0]);
    assert_vals(&x.reduce_mean(&[0], false).unwrap().value().unwrap(), &[2.5, 3.5, 4.5]);
    assert_vals(&x.reduce_max(&[1], false).unwrap().value().unwrap(), &[3.0, 6.0]);
    assert_vals(&x.reduce_sum(&[0, 1], false).unwrap().value().unwrap(), &[21.0]);
    let sm = x.softmax(1).unwrap().value().unwrap();
    let row: f32 = sm.as_f32().unwrap()[..3].iter().sum();
    assert!((row - 1.0).abs() < 1e-5);
    let lsm = x.log_softmax(1).unwrap().value().unwrap();
    for (a, b) in lsm.as_f32().unwrap().iter().zip(sm.as_f32().unwrap()) {
        assert!((a.exp() - b).abs() < 1e-5);
    }
}

#[test]
fn take_onehot_convert() {
    let s = session();
    s.begin_step(0).unwrap();
    let table = t(&s, &[3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    let idx = s.feed(HostTensor::i32(vec![2], vec![2, 0]).unwrap()).unwrap();
    assert_vals(&table.take(&idx, 0).unwrap().value().unwrap(), &[5.0, 6.0, 1.0, 2.0]);
    assert_vals(
        &idx.one_hot(3).unwrap().value().unwrap(),
        &[0.0, 0.0, 1.0, 1.0, 0.0, 0.0],
    );
    let f = idx.convert(DType::F32).unwrap().value().unwrap();
    assert_vals(&f, &[2.0, 0.0]);
    let back = t(&s, &[2], vec![2.9, -1.2]).convert(DType::I32).unwrap().value().unwrap();
    assert_eq!(back.as_i32().unwrap(), &[2, -1]);
}

#[test]
fn pow_ops() {
    let s = session();
    s.begin_step(0).unwrap();
    let x = t(&s, &[3], vec![2.0, 3.0, 4.0]);
    assert_vals(&x.pow_scalar(2.0).unwrap().value().unwrap(), &[4.0, 9.0, 16.0]);
    let e = t(&s, &[3], vec![0.5, 1.0, 2.0]);
    assert_vals(&x.pow(&e).unwrap().value().unwrap(), &[2.0f32.sqrt(), 3.0, 16.0]);
}

// ---------------------------------------------------------------------------
// gradients vs central finite differences
// ---------------------------------------------------------------------------

/// d/dx[i] of (sum of f(x)) via the tape, compared against central FD.
fn grad_check(f: impl Fn(&terra::api::Tensor) -> terra::error::Result<terra::api::Tensor>, x0: Vec<f32>, tol: f32) {
    let s = session();
    let n = x0.len();
    let v = s.variable("x", HostTensor::f32(vec![n], x0.clone()).unwrap(), true).unwrap();
    s.begin_step(0).unwrap();
    let tape = Tape::start(&s).unwrap();
    let y = f(&v.read()).unwrap().reduce_sum(&[0], false).unwrap();
    let grads = tape.gradient(&y, &[&v]).unwrap();
    let analytic = grads[0].value().unwrap().as_f32().unwrap().to_vec();
    s.end_step().unwrap();

    // FD oracle over a fresh eager session per probe point.
    let eps = 1e-3f32;
    for i in 0..n {
        let eval = |xs: &[f32]| -> f32 {
            let s2 = session();
            s2.begin_step(0).unwrap();
            let xt = t(&s2, &[n], xs.to_vec());
            let y = f(&xt).unwrap().reduce_sum(&[0], false).unwrap();
            y.value().unwrap().scalar_value_f32().unwrap()
        };
        let mut hi = x0.clone();
        hi[i] += eps;
        let mut lo = x0.clone();
        lo[i] -= eps;
        let fd = (eval(&hi) - eval(&lo)) / (2.0 * eps);
        assert!(
            (analytic[i] - fd).abs() <= tol * fd.abs().max(1.0),
            "component {i}: analytic {} vs fd {fd}",
            analytic[i]
        );
    }
}

#[test]
fn fd_grad_elementwise_chain() {
    grad_check(|x| x.mul(x)?.tanh(), vec![0.3, -0.6, 0.9], 2e-2);
}

#[test]
fn fd_grad_exp_log_mix() {
    grad_check(|x| x.exp()?.add_scalar(1.0)?.log(), vec![0.1, 0.7, -0.4], 2e-2);
}

#[test]
fn fd_grad_sigmoid_mul() {
    grad_check(|x| x.sigmoid()?.mul(x), vec![0.5, -1.0, 2.0], 2e-2);
}

#[test]
fn fd_grad_softmax_weighted() {
    grad_check(
        |x| {
            let sm = x.reshape(&[1, 3])?.softmax(1)?;
            let w = x.session().constant(HostTensor::f32(vec![1, 3], vec![1.0, 3.0, -2.0])?)?;
            sm.mul(&w)?.reduce_sum(&[0, 1], false)?.reshape(&[1])
        },
        vec![0.2, -0.1, 0.4],
        2e-2,
    );
}

#[test]
fn fd_grad_div_rsqrt() {
    grad_check(|x| x.add_scalar(3.0)?.rsqrt()?.div_scalar(2.0), vec![0.5, 1.5, 2.5], 2e-2);
}

#[test]
fn fd_grad_maximum_branches() {
    // away from the kink so FD is stable
    grad_check(
        |x| {
            let c = x.session().constant(HostTensor::f32(vec![3], vec![1.0, -5.0, 0.0])?)?;
            x.maximum(&c)
        },
        vec![2.0, -7.0, 3.0],
        2e-2,
    );
}

#[test]
fn fd_grad_matmul_quadratic() {
    grad_check(
        |x| {
            let m = x.reshape(&[1, 3])?;
            m.matmul(&m.transpose(&[1, 0])?)?.reshape(&[1])
        },
        vec![0.7, -0.2, 1.1],
        2e-2,
    );
}

#[test]
fn fd_grad_reduce_mean_pad_slice() {
    grad_check(
        |x| x.pad(&[1], &[1])?.slice(&[0], &[4])?.reduce_mean(&[0], true),
        vec![0.3, 0.6, -0.9],
        2e-2,
    );
}
