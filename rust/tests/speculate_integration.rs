//! Integration + property tests for the speculation subsystem: the
//! content-addressed plan cache (fallback→re-entry cycles with a
//! previously-seen graph signature skip the optimizer and every segment
//! compilation) and the adaptive re-entry controller (thrashing programs
//! back off instead of recompiling, and stay numerically exact).

use std::collections::HashMap;

use terra::api::{Session, Variable};
use terra::config::ExecMode;
use terra::error::Result;
use terra::programs::{Program, StepOutput, TrainMlp, TrainOptim};
use terra::runner::{Engine, EngineStats, RunReport};
use terra::speculate::{graph_signature, GraphSig, ReentryPolicy, SpeculateConfig};
use terra::tensor::HostTensor;

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_speculate_it_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    // Write-if-absent: tests in this binary run concurrently, and a truncate
    // rewrite could be observed half-written by a parallel ArtifactStore::open.
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        std::fs::write(manifest, r#"{"artifacts": []}"#).unwrap();
    }
    dir.to_string_lossy().into_owned()
}

/// Multi-path program: the op applied to `w * x` rotates every `phase_len`
/// steps through four distinct call sites. While a phase's path is novel the
/// engine diverges at the phase boundary (a fallback); once all four paths
/// are merged the alternation is absorbed by the TraceGraph's branch
/// machinery. A second engine instance replays the exact same signature
/// sequence — which is what the plan cache is for.
struct PhaseRotator {
    w: Option<Variable>,
    phase_len: u64,
}

impl Program for PhaseRotator {
    fn name(&self) -> &'static str {
        "phase_rotator"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        self.w = Some(sess.variable("w", HostTensor::scalar_f32(0.8), true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let x = sess.feed(HostTensor::scalar_f32(0.5 + (step % 7) as f32 * 0.01))?;
        let y = w.read().mul(&x)?;
        let z = match (step / self.phase_len) % 4 {
            0 => y.relu()?,
            1 => y.tanh()?,
            2 => y.sigmoid()?,
            _ => y.abs()?,
        };
        w.assign(&z)?;
        Ok(StepOutput { loss: Some(z), extra: vec![] })
    }
}

fn run_rotator(mode: ExecMode, spec: SpeculateConfig, steps: u64) -> (RunReport, f32) {
    let dir = artifacts_dir();
    let mut engine = Engine::with_speculate(mode, &dir, true, 2, spec).unwrap();
    let mut prog = PhaseRotator { w: None, phase_len: 5 };
    let report = engine.run(&mut prog, steps, 0).unwrap();
    let w = prog.w.as_ref().unwrap().id();
    let w_final = engine.vars().host(w).unwrap().scalar_value_f32().unwrap();
    (report, w_final)
}

fn assert_close(a: f32, b: f32, what: &str) {
    assert!(
        (a - b).abs() <= 1e-5 * a.abs().max(1.0),
        "{what}: {a} vs {b}"
    );
}

/// The headline property (ISSUE 3 acceptance): on a multi-path program that
/// diverges every M steps, co-execution entries with a previously-seen graph
/// signature perform zero optimizer passes and zero fresh segment compiles,
/// while the weights still track the eager oracle exactly.
#[test]
fn plan_cache_makes_reentries_free_and_exact() {
    let steps = 30; // phases 0,1,2,3,0,1 — later phases revisit merged paths
    let spec = SpeculateConfig {
        plan_cache: true,
        policy: ReentryPolicy::Adaptive,
        ..Default::default()
    };

    let (_, oracle_w) = run_rotator(ExecMode::Eager, spec, steps);

    // First instance: repeated divergence fallbacks, each re-entry compiling
    // a fresh (grown) graph and populating the cache.
    let (r1, w1) = run_rotator(ExecMode::Terra, spec, steps);
    assert!(r1.stats.fallbacks >= 3, "each new phase must diverge: {:?}", r1.stats);
    assert!(r1.stats.enter_coexec >= 3, "{:?}", r1.stats);
    assert_close(oracle_w, w1, "first instance diverged from eager oracle");

    // Second instance replays the same signature sequence: it still *falls
    // back* at every phase boundary (its own graph must grow), but every
    // re-entry is a cache hit — no optimizer pass runs, no segment compiles.
    let (r2, w2) = run_rotator(ExecMode::Terra, spec, steps);
    let s2: EngineStats = r2.stats;
    assert!(s2.fallbacks >= 3, "{s2:?}");
    assert!(s2.enter_coexec >= 3, "{s2:?}");
    assert_eq!(
        s2.plan_cache_hits, s2.enter_coexec,
        "every re-entry must be served by the plan cache: {s2:?}"
    );
    assert_eq!(s2.plan_cache_misses, 0, "{s2:?}");
    assert_eq!(s2.segments_compiled, 0, "segments_compiled must stop growing: {s2:?}");
    assert_eq!(s2.plans_generated, 0, "plan generation skipped entirely: {s2:?}");
    assert_eq!(r2.opt.pipelines, 0, "zero optimizer passes on cache hits");
    assert_eq!(s2.opt_rewrites + s2.opt_nodes_removed + s2.opt_nodes_folded, 0, "{s2:?}");
    assert!(s2.segment_compiles_skipped >= s2.plan_cache_hits, "{s2:?}");
    assert!(s2.reentry_ns > 0, "re-entry latency must be recorded: {s2:?}");
    assert_close(oracle_w, w2, "cached-plan instance diverged from eager oracle");

    // Same trajectory as the compiling instance, step for step.
    assert_eq!(r1.losses.len(), r2.losses.len());
    for ((s, a), (_, b)) in r1.losses.iter().zip(r2.losses.iter()) {
        assert_close(*a, *b, &format!("loss mismatch at step {s}"));
    }
}

/// Plan-cache knob off = seed behaviour: no cache traffic, no deferrals, and
/// still exact.
#[test]
fn disabled_speculation_is_seed_behaviour() {
    let steps = 20;
    let (_, oracle_w) = run_rotator(ExecMode::Eager, SpeculateConfig::disabled(), steps);
    let (r, w) = run_rotator(ExecMode::Terra, SpeculateConfig::disabled(), steps);
    assert_eq!(r.stats.plan_cache_hits, 0);
    assert_eq!(r.stats.plan_cache_misses, 0);
    assert_eq!(r.stats.segment_compiles_skipped, 0);
    assert_eq!(r.stats.reentry_deferred, 0, "eager policy never defers");
    assert!(r.stats.enter_coexec >= 1);
    assert_close(oracle_w, w, "disabled speculation diverged from eager oracle");
}

/// A pathologically dynamic program: the unrolled chain grows every other
/// step, so no trace shape ever recurs for long. The adaptive controller
/// must back off (defer re-entries) and end up with *fewer* fallbacks than
/// the eager seed policy — while both stay numerically exact.
struct GrowingChain {
    w: Option<Variable>,
}

impl Program for GrowingChain {
    fn name(&self) -> &'static str {
        "growing_chain"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        self.w = Some(sess.variable("w", HostTensor::scalar_f32(1.5), true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let x = sess.feed(HostTensor::scalar_f32(1.01 + (step % 3) as f32 * 0.001))?;
        let mut y = w.read().mul(&x)?;
        // Trip count grows every other step: 1, 1, 2, 2, 3, 3, ...
        for _ in 0..(step / 2 + 1) {
            y = y.tanh()?;
        }
        w.assign(&y)?;
        Ok(StepOutput { loss: Some(y), extra: vec![] })
    }
}

fn run_growing(mode: ExecMode, spec: SpeculateConfig, steps: u64) -> (EngineStats, f32, u32) {
    let dir = artifacts_dir();
    let mut engine = Engine::with_speculate(mode, &dir, true, 2, spec).unwrap();
    let mut prog = GrowingChain { w: None };
    let report = engine.run(&mut prog, steps, 0).unwrap();
    let required = engine.reentry_controller().required();
    let w = prog.w.as_ref().unwrap().id();
    let w_final = engine.vars().host(w).unwrap().scalar_value_f32().unwrap();
    (report.stats, w_final, required)
}

#[test]
fn adaptive_controller_stops_thrashing() {
    let steps = 16;
    let eager = SpeculateConfig {
        plan_cache: false,
        policy: ReentryPolicy::Eager,
        ..Default::default()
    };
    let adaptive = SpeculateConfig {
        plan_cache: false,
        policy: ReentryPolicy::Adaptive,
        ..Default::default()
    };

    let (_, oracle_w, _) = run_growing(ExecMode::Eager, eager, steps);
    let (es, ew, _) = run_growing(ExecMode::Terra, eager, steps);
    let (as_, aw, required) = run_growing(ExecMode::Terra, adaptive, steps);

    assert!(es.fallbacks >= 2, "the eager policy must thrash here: {es:?}");
    assert!(
        as_.fallbacks < es.fallbacks,
        "backoff must reduce fallbacks: adaptive {as_:?} vs eager {es:?}"
    );
    assert!(as_.reentry_deferred > 0, "backoff must defer re-entries: {as_:?}");
    assert!(required >= 2, "repeated thrashing must raise the stable-trace bar");

    // Correctness is untouched by when (or whether) the engine re-enters.
    assert_close(oracle_w, ew, "eager-policy run diverged from oracle");
    assert_close(oracle_w, aw, "adaptive run diverged from oracle");
}

/// Trace a full train step (forward + tape backward + fused Adam update) in
/// a fresh engine and return the merged TraceGraph's canonical signature.
fn train_step_signature(lr: Option<f32>, dim: Option<usize>) -> GraphSig {
    let dir = artifacts_dir();
    let spec = SpeculateConfig {
        plan_cache: false,
        policy: ReentryPolicy::Eager,
        split_hot_sites: false,
    };
    let mut engine = Engine::with_speculate(ExecMode::Terra, &dir, true, 2, spec).unwrap();
    let mut prog = TrainMlp::new(TrainOptim::Adam, true);
    if let Some(lr) = lr {
        prog = prog.with_lr(lr);
    }
    if let Some(dim) = dim {
        prog = prog.with_dim(dim);
    }
    engine.run(&mut prog, 8, 0).unwrap();
    let mut var_types = HashMap::new();
    for id in engine.vars().ids() {
        var_types.insert(id, engine.vars().ty(id).unwrap());
    }
    graph_signature(engine.trace_graph(), &var_types)
}

/// ISSUE satellite: gradient-graph signature stability. Two independent
/// sessions tracing the same train step — tape scopes, VJP emission order,
/// Adam slot updates and all — must produce the same 128-bit signature (this
/// is what makes cross-session gradient-plan cache hits possible at all),
/// while changing a hyperparameter baked into the graph (lr) or a variable
/// shape must change it.
#[test]
fn gradient_graph_signature_is_stable_across_sessions() {
    let a = train_step_signature(None, None);
    let b = train_step_signature(None, None);
    assert_eq!(a, b, "identical train steps must hash identically across sessions");

    let lr_changed = train_step_signature(Some(0.005), None);
    assert_ne!(a, lr_changed, "learning rate is a graph constant: changing it must re-key");

    let dim_changed = train_step_signature(None, Some(12));
    assert_ne!(a, dim_changed, "parameter shapes are part of the signature");
}

/// The profiler attributes fallbacks to divergence sites and tracks
/// inter-fallback distances.
#[test]
fn controller_profiles_divergence_sites() {
    let dir = artifacts_dir();
    let spec = SpeculateConfig {
        plan_cache: false,
        policy: ReentryPolicy::Adaptive,
        ..Default::default()
    };
    let mut engine = Engine::with_speculate(ExecMode::Terra, &dir, true, 2, spec).unwrap();
    let mut prog = PhaseRotator { w: None, phase_len: 4 };
    let report = engine.run(&mut prog, 20, 0).unwrap();
    assert!(report.stats.fallbacks >= 2, "{:?}", report.stats);
    let ctl = engine.reentry_controller();
    assert_eq!(ctl.fallbacks(), report.stats.fallbacks);
    let sites: u64 = ctl.hot_sites().iter().map(|(_, c)| c).sum();
    assert_eq!(sites, report.stats.fallbacks, "every fallback is attributed to a site");
    assert!(ctl.mean_fallback_distance().is_some());
}
