//! Deterministic fault-injection tests of the fault degradation ladder
//! (ISSUE 7): every symbolic-side failure — panic, error, or hang — must
//! resolve to the imperative fallback path without aborting the process,
//! and the run's observable results (losses, final variables) must match
//! the pure-eager oracle exactly.
//!
//! Exactness: these runs use `fusion = false, opt_level = 0`, so every plan
//! node compiles to the same single-op shim kernel the eager executor uses
//! — no fused-arithmetic reordering — which makes bitwise `assert_eq!`
//! against the eager oracle valid.
//!
//! Every Terra engine here installs its schedule via `set_fault_plan` and a
//! private `Quarantine`, so the tests are independent of any `TERRA_FAULTS`
//! / `TERRA_PLAN_MAX_FAULTS` in the environment (the CI fault matrix sets
//! those process-wide). The shim's worker-pool hooks are process-global, so
//! all tests serialize on one lock.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use terra::api::{Session, Variable};
use terra::config::ExecMode;
use terra::error::{FaultStage, Result, TerraError};
use terra::faults::FaultPlan;
use terra::programs::{Program, StepOutput, TinyLinear};
use terra::runner::Engine;
use terra::speculate::{Quarantine, ReentryPolicy, SpeculateConfig};
use terra::tensor::HostTensor;

fn serialize() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(Mutex::default).lock().unwrap_or_else(PoisonError::into_inner)
}

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_fault_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

/// Plan cache off (so every entry attempt actually runs the compile hook)
/// and eager re-entry (deterministic entry timing).
fn spec() -> SpeculateConfig {
    SpeculateConfig { plan_cache: false, policy: ReentryPolicy::Eager, split_hot_sites: false }
}

/// A Terra engine with an explicit fault schedule, a private quarantine
/// registry, and no watchdog unless a test arms one — independent of the
/// process environment.
fn fault_engine(dir: &str, schedule: &str, max_faults: u32) -> Engine {
    let mut engine = Engine::with_speculate(ExecMode::Terra, dir, false, 0, spec()).unwrap();
    engine.set_quarantine(Arc::new(Quarantine::with_max_faults(max_faults)));
    engine.set_fault_plan(Some(Arc::new(FaultPlan::parse(schedule, 0).unwrap())));
    engine.set_watchdog(None);
    engine
}

fn final_vars(engine: &Engine) -> Vec<HostTensor> {
    engine.vars().ids().into_iter().map(|id| engine.vars().host(id).unwrap()).collect()
}

/// Eager oracle for `prog`: same unfused/unoptimized kernels, no faults.
fn eager_oracle(
    dir: &str,
    prog: &mut dyn Program,
    steps: u64,
) -> (Vec<(u64, f32)>, Vec<HostTensor>) {
    let mut engine = Engine::with_speculate(ExecMode::Eager, dir, false, 0, spec()).unwrap();
    let report = engine.run(prog, steps, 0).unwrap();
    (report.losses, final_vars(&engine))
}

/// Run TinyLinear under Terra with `schedule` injected, and assert the run
/// completes with losses and final variables *bit-identical* to the eager
/// oracle. Returns the engine stats for schedule-specific assertions.
fn run_faulted_tiny(schedule: &str, max_faults: u32, steps: u64) -> terra::runner::EngineStats {
    let dir = artifacts_dir();
    let (eager_losses, eager_vars) = eager_oracle(&dir, &mut TinyLinear::new(0), steps);
    let mut engine = fault_engine(&dir, schedule, max_faults);
    let mut prog = TinyLinear::new(0);
    let report = engine
        .run(&mut prog, steps, 0)
        .unwrap_or_else(|e| panic!("faulted run must still complete ({schedule}): {e}"));
    assert_eq!(eager_losses, report.losses, "losses diverged from eager oracle ({schedule})");
    assert_eq!(eager_vars, final_vars(&engine), "final vars diverged ({schedule})");
    report.stats
}

#[test]
fn compile_panic_is_contained_and_retried() {
    let _g = serialize();
    // First co-execution entry panics inside the plan build; the engine
    // strikes the plan, backs off, and a later recompile succeeds.
    let stats = run_faulted_tiny("compile:*:iter=1", 3, 23);
    assert!(stats.faults_injected >= 1, "{stats:?}");
    assert!(stats.panics_recovered >= 1, "{stats:?}");
    assert!(stats.enter_coexec >= 1, "recompile after backoff must succeed: {stats:?}");
    assert_eq!(stats.plans_quarantined, 0, "{stats:?}");
}

#[test]
fn segment_exec_panic_degrades_to_replay() {
    let _g = serialize();
    let stats = run_faulted_tiny("segment_exec:panic:iter=2", 3, 23);
    assert!(stats.faults_injected >= 1, "{stats:?}");
    assert!(stats.panics_recovered >= 1, "{stats:?}");
    assert!(stats.degraded_steps >= 1, "{stats:?}");
}

#[test]
fn segment_exec_error_degrades_without_panic() {
    let _g = serialize();
    let stats = run_faulted_tiny("segment_exec:error:iter=2", 3, 23);
    assert!(stats.faults_injected >= 1, "{stats:?}");
    assert_eq!(stats.panics_recovered, 0, "error faults are not panics: {stats:?}");
    assert!(stats.degraded_steps >= 1, "{stats:?}");
}

#[test]
fn mailbox_error_cancels_and_replays() {
    let _g = serialize();
    let stats = run_faulted_tiny("mailbox:error:iter=1", 3, 23);
    assert!(stats.faults_injected >= 1, "{stats:?}");
    assert!(stats.degraded_steps >= 1, "{stats:?}");
}

#[test]
fn hang_is_cancelled_by_the_watchdog() {
    let _g = serialize();
    let dir = artifacts_dir();
    let steps = 23;
    let (eager_losses, eager_vars) = eager_oracle(&dir, &mut TinyLinear::new(0), steps);
    let mut engine = fault_engine(&dir, "segment_exec:hang:iter=2", 3);
    engine.set_watchdog(Some(Duration::from_millis(200)));
    let mut prog = TinyLinear::new(0);
    let report = engine.run(&mut prog, steps, 0).unwrap();
    assert_eq!(eager_losses, report.losses);
    assert_eq!(eager_vars, final_vars(&engine));
    let stats = report.stats;
    assert!(stats.faults_injected >= 1, "{stats:?}");
    assert!(stats.watchdog_timeouts >= 1, "{stats:?}");
    assert!(stats.degraded_steps >= 1, "{stats:?}");
}

#[test]
fn repeated_faults_quarantine_the_plan() {
    let _g = serialize();
    // Always-firing segment panic, two strikes allowed: entry 1 faults
    // (strike 1, backoff), entry 2 faults (strike 2, quarantined). The plan
    // must never re-enter co-execution over the remaining ~35 steps.
    let stats = run_faulted_tiny("segment_exec:panic", 2, 40);
    assert_eq!(stats.enter_coexec, 2, "quarantined plan re-entered co-execution: {stats:?}");
    assert_eq!(stats.plans_quarantined, 1, "{stats:?}");
    assert!(stats.panics_recovered >= 2, "{stats:?}");
    assert!(stats.degraded_steps >= 2, "{stats:?}");
}

/// Wide elementwise pipeline: tensors large enough (>= the shim pool's
/// 4096-element dispatch threshold) that kernels go parallel whenever the
/// worker pool has threads, so an armed worker-chunk fault actually lands
/// inside a pool chunk.
struct WidePipe {
    w: Option<Variable>,
}

impl Program for WidePipe {
    fn name(&self) -> &'static str {
        "wide_pipe"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        self.w = Some(sess.variable("w", HostTensor::filled_f32(vec![8192], 0.5), true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let x = sess.feed(HostTensor::filled_f32(vec![8192], 1.0 + step as f32 * 1e-3))?;
        let y = w.read().mul(&x)?.tanh()?;
        let loss_t = y.mul(&y)?.reduce_mean(&[0], false)?;
        w.assign(&y)?;
        Ok(StepOutput { loss: Some(loss_t), extra: vec![] })
    }
}

/// Restores the worker-thread override on the process's shared client (the
/// one engines built through `with_speculate` execute on) when dropped.
struct ThreadsOverride;

impl ThreadsOverride {
    fn set(n: usize) -> Self {
        terra::runtime::Client::global().set_threads(n);
        ThreadsOverride
    }
}

impl Drop for ThreadsOverride {
    fn drop(&mut self) {
        terra::runtime::Client::global().set_threads(0);
    }
}

#[test]
fn worker_chunk_panic_surfaces_as_error() {
    let _g = serialize();
    if xla::active_backend() != xla::ShimBackend::Bytecode {
        // The worker pool (and its chunk-fault hook) is bytecode-only.
        return;
    }
    let _threads = ThreadsOverride::set(2);
    let dir = artifacts_dir();
    let steps = 12;
    let (eager_losses, eager_vars) = eager_oracle(&dir, &mut WidePipe { w: None }, steps);
    // One strike allowed: the first chunk fault pins the plan to eager, so
    // the rest of the run is deterministic imperative execution.
    let mut engine = fault_engine(&dir, "worker:panic:chunk=0", 1);
    let mut prog = WidePipe { w: None };
    let report = engine.run(&mut prog, steps, 0).unwrap();
    assert_eq!(eager_losses, report.losses);
    assert_eq!(eager_vars, final_vars(&engine));
    let stats = report.stats;
    assert!(stats.faults_injected >= 1, "{stats:?}");
    // The pool's catch_unwind contains the chunk panic and surfaces it as an
    // execution `Err` — the runner sees an error, not an unwind.
    assert_eq!(stats.panics_recovered, 0, "{stats:?}");
    assert!(stats.degraded_steps >= 1, "{stats:?}");
    assert_eq!(stats.plans_quarantined, 1, "{stats:?}");
    assert_eq!(stats.enter_coexec, 1, "{stats:?}");
}

#[test]
fn wedged_runner_shutdown_is_bounded() {
    let _g = serialize();
    // A runner iteration hangs while the python side never blocks on a
    // fetch (loss_every = 0 materializes nothing), so the hang is only
    // discovered at shutdown. The drain must give up at the watchdog
    // deadline, abandon the wedged thread, and report a watchdog fault —
    // bounded, not a process hang.
    let dir = artifacts_dir();
    let mut engine = fault_engine(&dir, "segment_exec:hang:iter=2", 3);
    engine.set_watchdog(Some(Duration::from_millis(300)));
    engine.loss_every = 0;
    let mut prog = TinyLinear::new(0);
    engine.setup(&mut prog).unwrap();
    for step in 0..6 {
        engine.run_step(&mut prog, step).unwrap();
    }
    let t0 = Instant::now();
    let err = engine.shutdown().expect_err("undrained iterations must be reported");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown not bounded: took {:?}",
        t0.elapsed()
    );
    match err {
        TerraError::Fault(f) => assert_eq!(f.stage, FaultStage::Watchdog, "{f:?}"),
        other => panic!("expected a watchdog fault, got: {other}"),
    }
    assert!(engine.stats().watchdog_timeouts >= 1, "{:?}", engine.stats());
}
