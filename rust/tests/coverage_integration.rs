//! Table-1 semantics: the AutoGraph-style baseline must fail on exactly the
//! five programs the paper reports (with the right failure categories), and
//! Terra must execute all ten.

use terra::config::ExecMode;
use terra::error::TerraError;
use terra::programs::{all_program_names, build_program, expected_autograph_failure};
use terra::runner::Engine;

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_cov_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

fn run_autograph(name: &str, steps: u64) -> Result<(), TerraError> {
    let dir = artifacts_dir();
    let mut engine = Engine::new(ExecMode::AutoGraph, &dir, true)?;
    let mut prog = build_program(name)?;
    engine.run(prog.as_mut(), steps, 0).map(|_| ())
}

#[test]
fn autograph_failure_matrix_matches_table1() {
    for name in all_program_names() {
        let expected = expected_autograph_failure(name);
        let got = run_autograph(name, 12);
        match (expected, got) {
            (None, Ok(())) => {}
            (Some(cat), Err(TerraError::Convert { category, .. })) => {
                assert_eq!(
                    category, cat,
                    "{name}: expected failure category {cat:?}, got {category:?}"
                );
            }
            (None, Err(e)) => panic!("{name}: AutoGraph should succeed but failed: {e}"),
            (Some(cat), Ok(())) => panic!("{name}: AutoGraph should fail with {cat:?} but ran"),
            (Some(cat), Err(e)) => {
                panic!("{name}: expected conversion failure {cat:?}, got other error: {e}")
            }
        }
    }
}

#[test]
fn terra_executes_all_ten_programs() {
    // (Terra per-program correctness is covered by programs_integration;
    // here we only assert coverage: no program errors out.)
    let dir = artifacts_dir();
    for name in all_program_names() {
        let mut engine = Engine::new(ExecMode::Terra, &dir, true).unwrap();
        let mut prog = build_program(name).unwrap();
        let report = engine
            .run(prog.as_mut(), 6, 0)
            .unwrap_or_else(|e| panic!("Terra failed on {name}: {e}"));
        assert!(report.steps == 6);
    }
}

#[test]
fn autograph_succeeds_and_matches_eager_on_supported_program() {
    // For a supported, deterministic program the baseline's numerics must
    // match imperative execution (it is a faithful graph of the step).
    let dir = artifacts_dir();
    let steps = 8;
    let run = |mode: ExecMode| {
        let mut engine = Engine::new(mode, &dir, true).unwrap();
        let mut prog = build_program("resnet50").unwrap();
        let report = engine.run(prog.as_mut(), steps, 0).unwrap();
        report.losses
    };
    let eager = run(ExecMode::Eager);
    let ag = run(ExecMode::AutoGraph);
    assert_eq!(eager.len(), ag.len());
    for ((s, a), (_, b)) in eager.iter().zip(ag.iter()) {
        assert!(
            (a - b).abs() <= 2e-4 * a.abs().max(1.0),
            "autograph numerics diverge at step {s}: {a} vs {b}"
        );
    }
}
