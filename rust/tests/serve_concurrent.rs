//! Multi-tenant serving stress tests (ISSUE 9 acceptance): N sessions of one
//! [`terra::serve::Runtime`] running concurrently must produce per-session
//! results bit-identical to each session running alone — the shared plan
//! cache serves cross-session hits without staleness, each session's private
//! client RNG stream is isolated from its neighbours, and the shared worker
//! budget changes latency only, never numerics.

use terra::api::{Session, Variable};
use terra::config::{ExecMode, RunConfig};
use terra::error::Result;
use terra::programs::{Program, StepOutput};
use terra::serve::{Runtime, RuntimeConfig};
use terra::speculate::{ReentryPolicy, SpeculateConfig};
use terra::tensor::HostTensor;

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_serve_it_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    // Write-if-absent: tests in this binary run concurrently, and a truncate
    // rewrite could be observed half-written by a parallel ArtifactStore::open.
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        std::fs::write(manifest, r#"{"artifacts": []}"#).unwrap();
    }
    dir.to_string_lossy().into_owned()
}

fn serve_cfg() -> RunConfig {
    RunConfig {
        mode: ExecMode::Terra,
        artifacts_dir: artifacts_dir(),
        // Pin the speculation knobs (the default reads env) so every engine
        // in this binary replays the same signature sequence
        // deterministically.
        speculate: SpeculateConfig {
            plan_cache: true,
            policy: ReentryPolicy::Adaptive,
            split_hot_sites: false,
        },
        ..RunConfig::default()
    }
}

/// Single-path program with an RNG draw every step: `w <- tanh(w*x + 0.01*u)`
/// where `u` comes from the session's private client stream. Two sessions
/// running this concurrently only agree with their solo runs if their RNG
/// streams never cross.
struct NoisyScale {
    w: Option<Variable>,
    scale: f32,
}

impl Program for NoisyScale {
    fn name(&self) -> &'static str {
        "serve_noisy_scale"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        self.w = Some(sess.variable("w", HostTensor::filled_f32(vec![8], 0.6), true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let x = sess.feed(HostTensor::filled_f32(
            vec![8],
            1.0 + step as f32 * 1e-3 * self.scale,
        ))?;
        let u = sess.rng_uniform(&[8])?;
        let y = w.read().mul(&x)?.add(&u.mul_scalar(0.01)?)?.tanh()?;
        let loss = y.mul(&y)?.reduce_mean(&[0], false)?;
        w.assign(&y)?;
        Ok(StepOutput { loss: Some(loss), extra: vec![] })
    }
}

/// Multi-path program (four call sites rotating every `phase_len` steps):
/// each phase boundary is a divergence fallback and a co-execution re-entry,
/// so one run touches the plan cache several times with distinct signatures.
struct Rotator {
    w: Option<Variable>,
    phase_len: u64,
}

impl Program for Rotator {
    fn name(&self) -> &'static str {
        "serve_rotator"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        self.w = Some(sess.variable("w", HostTensor::scalar_f32(0.7), true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let x = sess.feed(HostTensor::scalar_f32(0.5 + (step % 5) as f32 * 0.02))?;
        let y = w.read().mul(&x)?;
        let z = match (step / self.phase_len) % 4 {
            0 => y.relu()?,
            1 => y.tanh()?,
            2 => y.sigmoid()?,
            _ => y.abs()?,
        };
        w.assign(&z)?;
        Ok(StepOutput { loss: Some(z), extra: vec![] })
    }
}

/// What one session run leaves behind, for exact comparison.
struct Outcome {
    losses: Vec<(u64, f32)>,
    rng_state: u64,
    stats: terra::runner::EngineStats,
}

/// Run `prog` alone: a private runtime (fresh plan cache, fresh budget), one
/// session, serial execution. The ground truth every concurrent run must hit
/// bit for bit.
fn solo(make: &dyn Fn() -> Box<dyn Program>, steps: u64) -> Outcome {
    let rt = Runtime::with_defaults().unwrap();
    let cfg = serve_cfg();
    let mut sess = rt.open_session(&cfg).unwrap();
    let mut prog = make();
    let report = sess.run(prog.as_mut(), steps, 0).unwrap();
    Outcome {
        losses: report.losses,
        rng_state: sess.engine().client().rng_state(),
        stats: report.stats,
    }
}

/// Run every program in `makes` concurrently, one session each, on a shared
/// runtime. Returns outcomes in input order.
fn concurrent(
    rt: &Runtime,
    makes: &[&(dyn Fn() -> Box<dyn Program> + Sync)],
    steps: u64,
) -> Vec<Outcome> {
    let cfg = serve_cfg();
    let mut sessions: Vec<_> = makes.iter().map(|_| rt.open_session(&cfg).unwrap()).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = sessions
            .iter_mut()
            .zip(makes.iter())
            .map(|(sess, make)| {
                s.spawn(move || {
                    let mut prog = make();
                    let report = sess.run(prog.as_mut(), steps, 0).unwrap();
                    Outcome {
                        losses: report.losses,
                        rng_state: sess.engine().client().rng_state(),
                        stats: report.stats,
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn assert_identical(solo: &Outcome, concurrent: &Outcome, who: &str) {
    assert_eq!(
        solo.losses.len(),
        concurrent.losses.len(),
        "{who}: step counts differ"
    );
    for ((s, a), (_, b)) in solo.losses.iter().zip(concurrent.losses.iter()) {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{who}: loss at step {s} not bit-identical ({a} vs {b})"
        );
    }
    assert_eq!(
        solo.rng_state, concurrent.rng_state,
        "{who}: client RNG stream diverged from the solo run"
    );
}

/// The headline acceptance property: three sessions of the *same* program
/// shape (identical graph signatures — the shared cache and build coalescing
/// are fully exercised) but distinct data trajectories and private RNG
/// streams, run concurrently, each land bit-identical to running alone.
#[test]
fn concurrent_sessions_bit_identical_to_solo() {
    let steps = 24;
    let scales = [1.0f32, 2.0, 3.0];
    let solos: Vec<Outcome> = scales
        .iter()
        .map(|&sc| {
            solo(&move || Box::new(NoisyScale { w: None, scale: sc }) as Box<dyn Program>, steps)
        })
        .collect();
    // Distinct trajectories: the data (and therefore the losses) must differ
    // across scales, or the isolation assertions below are vacuous.
    assert_ne!(
        solos[0].losses.last().unwrap().1.to_bits(),
        solos[1].losses.last().unwrap().1.to_bits(),
        "scales must produce distinct trajectories"
    );

    let rt = Runtime::with_defaults().unwrap();
    let mk0 = || Box::new(NoisyScale { w: None, scale: 1.0 }) as Box<dyn Program>;
    let mk1 = || Box::new(NoisyScale { w: None, scale: 2.0 }) as Box<dyn Program>;
    let mk2 = || Box::new(NoisyScale { w: None, scale: 3.0 }) as Box<dyn Program>;
    let outcomes = concurrent(&rt, &[&mk0, &mk1, &mk2], steps);
    for (i, (s, c)) in solos.iter().zip(outcomes.iter()).enumerate() {
        assert_identical(s, c, &format!("session scale={}", scales[i]));
    }
    assert_eq!(rt.sessions_opened(), 3);
    assert_eq!(rt.active_runs(), 0, "all admission slots released");
    // The budget pool must end fully released no matter how execution
    // interleaved (RAII claims).
    assert_eq!(rt.budget().in_use(), 0);
}

/// Zero cross-session plan-cache staleness, deterministically: one session
/// warms the shared cache serially, then two more sessions replay the same
/// signature sequence concurrently. Every one of their re-entries must be a
/// cache hit (no compiles at all), and the numbers must still match a solo
/// run on a *cold* cache — i.e. a plan compiled by session 1 executed on
/// session 2's client produces session 2's exact results.
#[test]
fn warm_shared_cache_serves_sessions_exactly() {
    let steps = 30; // phases 0,1,2,3,0,1 at phase_len 5
    let mk = || Box::new(Rotator { w: None, phase_len: 5 }) as Box<dyn Program>;
    let cold = solo(&mk, steps);
    assert!(
        cold.stats.plan_cache_misses >= 1,
        "cold run must build plans: {:?}",
        cold.stats
    );

    let rt = Runtime::with_defaults().unwrap();
    let warm_run = concurrent(&rt, &[&mk], steps);
    assert_identical(&cold, &warm_run[0], "cache-warming session");

    let warmed = concurrent(&rt, &[&mk, &mk], steps);
    for (i, outcome) in warmed.iter().enumerate() {
        assert_identical(&cold, outcome, &format!("warmed session {i}"));
        let st = &outcome.stats;
        assert!(st.enter_coexec >= 3, "rotator must re-enter repeatedly: {st:?}");
        assert_eq!(
            st.plan_cache_misses, 0,
            "warmed session {i} must never build: {st:?}"
        );
        assert_eq!(
            st.plan_cache_hits, st.enter_coexec,
            "every re-entry served by the shared cache: {st:?}"
        );
        assert_eq!(st.segments_compiled, 0, "no fresh compiles: {st:?}");
    }
}

/// Different program shapes (disjoint signature sets) sharing one runtime:
/// concurrent tenants must not perturb each other through the shared cache,
/// budget, or quarantine.
#[test]
fn mixed_programs_one_runtime_no_interference() {
    let steps = 25;
    let mk_noisy = || Box::new(NoisyScale { w: None, scale: 1.5 }) as Box<dyn Program>;
    let mk_rot = || Box::new(Rotator { w: None, phase_len: 6 }) as Box<dyn Program>;
    let solo_noisy = solo(&mk_noisy, steps);
    let solo_rot = solo(&mk_rot, steps);

    let rt = Runtime::with_defaults().unwrap();
    let outcomes = concurrent(&rt, &[&mk_noisy, &mk_rot], steps);
    assert_identical(&solo_noisy, &outcomes[0], "noisy-scale tenant");
    assert_identical(&solo_rot, &outcomes[1], "rotator tenant");
}

/// A budget of 1 total thread (zero shared pool workers: every execution is
/// dispatch-thread-only) plus an admission cap of 1 fully serializes the
/// tenants — and, per the determinism contract, changes nothing numerically.
#[test]
fn budget_one_serializes_compute_without_changing_results() {
    let steps = 24;
    let mk0 = || Box::new(NoisyScale { w: None, scale: 1.0 }) as Box<dyn Program>;
    let mk1 = || Box::new(Rotator { w: None, phase_len: 5 }) as Box<dyn Program>;
    let solo0 = solo(&mk0, steps);
    let solo1 = solo(&mk1, steps);

    let rt = Runtime::new(RuntimeConfig { budget: 1, max_active: 1 }).unwrap();
    assert_eq!(rt.budget_cap(), 1);
    assert_eq!(rt.budget().cap(), 0, "budget 1 = no extra pool workers");
    let outcomes = concurrent(&rt, &[&mk0, &mk1], steps);
    assert_identical(&solo0, &outcomes[0], "budget-1 session 0");
    assert_identical(&solo1, &outcomes[1], "budget-1 session 1");
    assert_eq!(rt.budget().in_use(), 0);
    assert_eq!(rt.active_runs(), 0);
}
