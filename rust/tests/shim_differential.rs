//! Differential property tests: the vendored XLA shim's bytecode backend
//! must be bit-identical to the retained tree interpreter (the oracle) over
//! a generated op corpus — including deterministic RNG draws and the
//! RNG-stream alignment contract (dead RNG nodes still consume draws).

use terra::data::Rng;
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PrimitiveType, ShimBackend, XlaBuilder, XlaComputation, XlaOp};

const MAX_ELEMS: usize = 4096;

struct Val {
    op: XlaOp,
    prim: PrimitiveType,
    dims: Vec<i64>,
}

impl Val {
    fn n(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
    fn is_f(&self) -> bool {
        self.prim == PrimitiveType::F32
    }
}

enum ArgData {
    F { data: Vec<f32>, dims: Vec<usize> },
    I { data: Vec<i32>, dims: Vec<usize> },
}

fn random_dims(rng: &mut Rng, max_rank: usize, max_sz: usize) -> Vec<i64> {
    let rank = rng.below(max_rank + 1);
    (0..rank).map(|_| 1 + rng.below(max_sz) as i64).collect()
}

fn push(vals: &mut Vec<Val>, op: XlaOp, prim: PrimitiveType, dims: Vec<i64>) {
    vals.push(Val { op, prim, dims });
}

/// Pick an index of a value satisfying `pred`, if any.
fn pick_where(vals: &[Val], rng: &mut Rng, pred: impl Fn(&Val) -> bool) -> Option<usize> {
    let cands: Vec<usize> =
        (0..vals.len()).filter(|&i| pred(&vals[i])).collect();
    if cands.is_empty() {
        None
    } else {
        Some(cands[rng.below(cands.len())])
    }
}

/// Append one random op to the pool (no-op if no applicable operands).
fn add_random_op(b: &XlaBuilder, vals: &mut Vec<Val>, rng: &mut Rng, allow_rng: bool) {
    // 0..=17 are deterministic op kinds; 18..=19 is the RNG-op arm.
    let choice = rng.below(20);
    match choice {
        // Unary.
        0 | 1 => {
            let Some(i) = pick_where(vals, rng, |_| true) else { return };
            let v = &vals[i];
            let (op, prim, dims) = if v.is_f() {
                let o = match rng.below(9) {
                    0 => v.op.neg(),
                    1 => v.op.exp(),
                    2 => v.op.log(),
                    3 => v.op.sqrt(),
                    4 => v.op.rsqrt(),
                    5 => v.op.tanh(),
                    6 => v.op.logistic(),
                    7 => v.op.abs(),
                    _ => v.op.sign(),
                };
                (o.unwrap(), v.prim, v.dims.clone())
            } else {
                let o = match rng.below(3) {
                    0 => v.op.neg(),
                    1 => v.op.abs(),
                    _ => v.op.sign(),
                };
                (o.unwrap(), v.prim, v.dims.clone())
            };
            push(vals, op, prim, dims);
        }
        // ZerosLike.
        2 => {
            let Some(i) = pick_where(vals, rng, |_| true) else { return };
            let v = &vals[i];
            let (op, prim, dims) = (v.op.zeros_like().unwrap(), v.prim, v.dims.clone());
            push(vals, op, prim, dims);
        }
        // Binary (same shape or scalar broadcast; fused path).
        3 | 4 => {
            let Some(ai) = pick_where(vals, rng, |_| true) else { return };
            let (aprim, adims) = (vals[ai].prim, vals[ai].dims.clone());
            let af = vals[ai].is_f();
            let an = vals[ai].n();
            let Some(bi) = pick_where(vals, rng, |w| {
                w.is_f() == af && (w.dims == adims || w.n() == 1 || an == 1)
            }) else {
                return;
            };
            let out_dims = if an == 1 { vals[bi].dims.clone() } else { adims };
            let a = vals[ai].op.clone();
            let bb = vals[bi].op.clone();
            let o = match rng.below(7) {
                0 => a.add_(&bb),
                1 => a.sub_(&bb),
                2 => a.mul_(&bb),
                3 => a.div_(&bb),
                4 => a.max(&bb),
                5 => a.min(&bb),
                _ => a.pow(&bb),
            };
            push(vals, o.unwrap(), aprim, out_dims);
        }
        // Binary with a real broadcast (keep-dims reduce of self -> [..,1]).
        5 => {
            let Some(ai) =
                pick_where(vals, rng, |w| w.is_f() && !w.dims.is_empty() && w.n() <= MAX_ELEMS)
            else {
                return;
            };
            let v = &vals[ai];
            let d = rng.below(v.dims.len()) as i64;
            let red = v.op.reduce_sum(&[d], true).unwrap();
            let mut rdims = v.dims.clone();
            rdims[d as usize] = 1;
            let out = v.op.sub_(&red).unwrap();
            let out_dims = v.dims.clone();
            let prim = v.prim;
            push(vals, red, prim, rdims);
            push(vals, out, prim, out_dims);
        }
        // Compare.
        6 => {
            let Some(ai) = pick_where(vals, rng, |_| true) else { return };
            let (adims, af, an) = (vals[ai].dims.clone(), vals[ai].is_f(), vals[ai].n());
            let Some(bi) = pick_where(vals, rng, |w| {
                w.is_f() == af && (w.dims == adims || w.n() == 1 || an == 1)
            }) else {
                return;
            };
            let out_dims = if an == 1 { vals[bi].dims.clone() } else { adims };
            let a = vals[ai].op.clone();
            let bb = vals[bi].op.clone();
            let o = match rng.below(6) {
                0 => a.gt(&bb),
                1 => a.ge(&bb),
                2 => a.lt(&bb),
                3 => a.le(&bb),
                4 => a.eq(&bb),
                _ => a.ne(&bb),
            };
            push(vals, o.unwrap(), PrimitiveType::Pred, out_dims);
        }
        // Select (pred built from a same-shape compare).
        7 => {
            let Some(ti) = pick_where(vals, rng, |_| true) else { return };
            let (tdims, tf, tprim) = (vals[ti].dims.clone(), vals[ti].is_f(), vals[ti].prim);
            let Some(fi) = pick_where(vals, rng, |w| w.dims == tdims && w.is_f() == tf) else {
                return;
            };
            let t = vals[ti].op.clone();
            let f = vals[fi].op.clone();
            let pred = t.ne(&f).unwrap();
            let sel = pred.select(&t, &f).unwrap();
            push(vals, pred, PrimitiveType::Pred, tdims.clone());
            push(vals, sel, tprim, tdims);
        }
        // MatMul built from iotas scaled by a data-derived scalar.
        8 => {
            let Some(si) = pick_where(vals, rng, |w| w.is_f()) else { return };
            let rd: Vec<i64> = (0..vals[si].dims.len() as i64).collect();
            let scalar = vals[si].op.reduce_mean(&rd, false).unwrap();
            let m = 2 + rng.below(6) as i64;
            let k = 2 + rng.below(6) as i64;
            let nn = 2 + rng.below(6) as i64;
            let ia = b.iota1(ElementType::F32, (m * k) as usize).unwrap();
            let ib = b.iota1(ElementType::F32, (k * nn) as usize).unwrap();
            let half = b.c0(0.25f32).unwrap();
            let a2 = ia.mul_(&scalar).unwrap().reshape(&[m, k]).unwrap();
            let b2 = ib.mul_(&half).unwrap().reshape(&[k, nn]).unwrap();
            if rng.below(3) == 0 {
                // Batched lhs against a shared 2-d rhs.
                let bb = 2 + rng.below(2) as i64;
                if (bb * m * nn) as usize <= MAX_ELEMS {
                    let a3 = a2.broadcast(&[bb]).unwrap();
                    let mm = a3.matmul(&b2).unwrap();
                    push(vals, mm, PrimitiveType::F32, vec![bb, m, nn]);
                }
            } else {
                let mm = a2.matmul(&b2).unwrap();
                push(vals, mm, PrimitiveType::F32, vec![m, nn]);
            }
            push(vals, scalar, PrimitiveType::F32, vec![]);
        }
        // Transpose with a random permutation.
        9 => {
            let Some(i) = pick_where(vals, rng, |w| !w.dims.is_empty()) else { return };
            let v = &vals[i];
            let r = v.dims.len();
            let mut perm: Vec<i64> = (0..r as i64).collect();
            for x in (1..r).rev() {
                let y = rng.below(x + 1);
                perm.swap(x, y);
            }
            let out_dims: Vec<i64> = perm.iter().map(|&p| v.dims[p as usize]).collect();
            let (op, prim) = (v.op.transpose(&perm).unwrap(), v.prim);
            push(vals, op, prim, out_dims);
        }
        // Reshape (flatten or column).
        10 => {
            let Some(i) = pick_where(vals, rng, |_| true) else { return };
            let v = &vals[i];
            let n = v.n() as i64;
            let dims = match rng.below(3) {
                0 => vec![n],
                1 => vec![1, n],
                _ => vec![n, 1],
            };
            let (op, prim) = (v.op.reshape(&dims).unwrap(), v.prim);
            push(vals, op, prim, dims);
        }
        // Broadcast: prepend major dims.
        11 => {
            let Some(i) = pick_where(vals, rng, |w| w.n() * 6 <= MAX_ELEMS) else { return };
            let v = &vals[i];
            let sizes = vec![1 + rng.below(3) as i64];
            let mut out_dims = sizes.clone();
            out_dims.extend_from_slice(&v.dims);
            let (op, prim) = (v.op.broadcast(&sizes).unwrap(), v.prim);
            push(vals, op, prim, out_dims);
        }
        // BroadcastInDim: new major dim via identity-shifted mapping.
        12 => {
            let Some(i) = pick_where(vals, rng, |w| w.n() * 4 <= MAX_ELEMS) else { return };
            let v = &vals[i];
            let z = 1 + rng.below(3) as i64;
            let mut out_dims = vec![z];
            out_dims.extend_from_slice(&v.dims);
            let bdims: Vec<i64> = (1..=v.dims.len() as i64).collect();
            let (op, prim) = (v.op.broadcast_in_dim(&out_dims, &bdims).unwrap(), v.prim);
            push(vals, op, prim, out_dims);
        }
        // Concat with itself along a random dim.
        13 => {
            let Some(i) =
                pick_where(vals, rng, |w| !w.dims.is_empty() && w.n() * 2 <= MAX_ELEMS)
            else {
                return;
            };
            let v = &vals[i];
            let d = rng.below(v.dims.len()) as i64;
            let mut out_dims = v.dims.clone();
            out_dims[d as usize] *= 2;
            let (op, prim) = (v.op.concat_in_dim(&[&v.op], d).unwrap(), v.prim);
            push(vals, op, prim, out_dims);
        }
        // Slice.
        14 => {
            let Some(i) = pick_where(vals, rng, |w| !w.dims.is_empty()) else { return };
            let v = &vals[i];
            let d = rng.below(v.dims.len());
            let len = v.dims[d] as usize;
            let start = rng.below(len) as i64;
            let stop = start + 1 + rng.below(len - start as usize) as i64;
            let mut out_dims = v.dims.clone();
            out_dims[d] = stop - start;
            let (op, prim) = (v.op.slice_in_dim1(start, stop, d as i64).unwrap(), v.prim);
            push(vals, op, prim, out_dims);
        }
        // Reduce.
        15 => {
            let Some(i) = pick_where(vals, rng, |w| !w.dims.is_empty()) else { return };
            let v = &vals[i];
            let d = rng.below(v.dims.len()) as i64;
            let keep = rng.below(2) == 0;
            let kind = if v.is_f() { rng.below(3) } else { rng.below(2) };
            let o = match kind {
                0 => v.op.reduce_sum(&[d], keep),
                1 => v.op.reduce_max(&[d], keep),
                _ => v.op.reduce_mean(&[d], keep),
            };
            let mut out_dims = Vec::new();
            for (j, &x) in v.dims.iter().enumerate() {
                if j as i64 == d {
                    if keep {
                        out_dims.push(1);
                    }
                } else {
                    out_dims.push(x);
                }
            }
            let prim = v.prim;
            push(vals, o.unwrap(), prim, out_dims);
        }
        // Softmax + take.
        16 => {
            if rng.below(2) == 0 {
                let Some(i) = pick_where(vals, rng, |w| w.is_f() && !w.dims.is_empty()) else {
                    return;
                };
                let v = &vals[i];
                let d = rng.below(v.dims.len()) as i64;
                let (op, dims) = (v.op.softmax(d).unwrap(), v.dims.clone());
                push(vals, op, PrimitiveType::F32, dims);
            } else {
                let Some(di) = pick_where(vals, rng, |w| !w.dims.is_empty()) else { return };
                let (ddims, dprim) = (vals[di].dims.clone(), vals[di].prim);
                let d = rng.below(ddims.len());
                let k = 1 + rng.below(4);
                let idx = b.iota1(ElementType::S32, k).unwrap();
                let inner: i64 = ddims[d + 1..].iter().product();
                let outer: i64 = ddims[..d].iter().product();
                if (outer * k as i64 * inner.max(1)) as usize > MAX_ELEMS {
                    return;
                }
                let mut out_dims: Vec<i64> = ddims[..d].to_vec();
                out_dims.push(k as i64);
                out_dims.extend_from_slice(&ddims[d + 1..]);
                let op = vals[di].op.take(&idx, d as i64).unwrap();
                push(vals, idx, PrimitiveType::S32, vec![k as i64]);
                push(vals, op, dprim, out_dims);
            }
        }
        // Convert (including the same-type alias path).
        17 => {
            let Some(i) = pick_where(vals, rng, |_| true) else { return };
            let v = &vals[i];
            let target = if v.is_f() {
                match rng.below(3) {
                    0 => PrimitiveType::S32,
                    1 => PrimitiveType::Pred,
                    _ => PrimitiveType::F32,
                }
            } else {
                match rng.below(3) {
                    0 => PrimitiveType::F32,
                    1 => PrimitiveType::S32,
                    _ => PrimitiveType::Pred,
                }
            };
            let (op, dims) = (v.op.convert(target).unwrap(), v.dims.clone());
            push(vals, op, target, dims);
        }
        _ => {
            if allow_rng {
                let dims = random_dims(rng, 2, 5);
                let lo = b.c0(-1.0f32 - rng.unit()).unwrap();
                let hi = b.c0(1.0f32 + rng.unit()).unwrap();
                let sh = xla::ArrayShape::new::<f32>(dims.clone());
                let op = if rng.below(2) == 0 {
                    XlaOp::rng_uniform(&lo, &hi, &sh).unwrap()
                } else {
                    XlaOp::rng_normal(&lo, &hi, &sh).unwrap()
                };
                push(vals, op, PrimitiveType::F32, dims);
            }
        }
    }
}

fn build_case(seed: u64, allow_rng: bool) -> (XlaComputation, Vec<ArgData>) {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ 0xC0FF_EE00);
    let b = XlaBuilder::new("fuzz");
    let mut vals: Vec<Val> = Vec::new();
    let mut args: Vec<ArgData> = Vec::new();
    let n_params = 1 + rng.below(3);
    for pi in 0..n_params {
        let dims = random_dims(&mut rng, 3, 4);
        let n: usize = dims.iter().map(|&d| d as usize).product();
        let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        if rng.below(4) == 0 {
            let data: Vec<i32> = (0..n).map(|_| rng.below(9) as i32 - 4).collect();
            let op = b.parameter(pi as i64, ElementType::S32, &dims, "p").unwrap();
            args.push(ArgData::I { data, dims: udims });
            push(&mut vals, op, PrimitiveType::S32, dims);
        } else {
            let data = rng.normal_vec(n, 1.5);
            let op = b.parameter(pi as i64, ElementType::F32, &dims, "p").unwrap();
            args.push(ArgData::F { data, dims: udims });
            push(&mut vals, op, PrimitiveType::F32, dims);
        }
    }
    // Seed the pool with a couple of scalar constants (splat material).
    push(
        &mut vals,
        b.c0(rng.uniform(-2.0, 2.0)).unwrap(),
        PrimitiveType::F32,
        vec![],
    );
    push(&mut vals, b.c0(rng.below(7) as i32 - 3).unwrap(), PrimitiveType::S32, vec![]);
    let n_ops = 6 + rng.below(30);
    for _ in 0..n_ops {
        add_random_op(&b, &mut vals, &mut rng, allow_rng);
    }
    let k = 1 + rng.below(3);
    let mut outs: Vec<XlaOp> = Vec::new();
    for _ in 0..k {
        outs.push(vals[rng.below(vals.len())].op.clone());
    }
    let root = if outs.len() == 1 && rng.below(2) == 0 {
        outs[0].clone()
    } else {
        b.tuple(&outs).unwrap()
    };
    (b.build(&root).unwrap(), args)
}

fn make_buffers(client: &PjRtClient, args: &[ArgData]) -> Vec<PjRtBuffer> {
    args.iter()
        .map(|a| match a {
            ArgData::F { data, dims } => {
                client.buffer_from_host_buffer::<f32>(data, dims, None).unwrap()
            }
            ArgData::I { data, dims } => {
                client.buffer_from_host_buffer::<i32>(data, dims, None).unwrap()
            }
        })
        .collect()
}

/// A shape+bitwise fingerprint of one output leaf.
fn fingerprint(lit: &Literal) -> (PrimitiveType, Vec<i64>, Vec<u32>) {
    let sh = lit.array_shape().unwrap();
    let bits: Vec<u32> = match sh.primitive_type() {
        PrimitiveType::F32 => lit.to_vec::<f32>().unwrap().iter().map(|v| v.to_bits()).collect(),
        _ => lit.to_vec::<i32>().unwrap().iter().map(|&v| v as u32).collect(),
    };
    (sh.primitive_type(), sh.dims().to_vec(), bits)
}

type RunOut = Result<Vec<(PrimitiveType, Vec<i64>, Vec<u32>)>, String>;

fn run_backend(comp: &XlaComputation, args: &[ArgData], backend: ShimBackend) -> RunOut {
    run_backend_with(comp, args, backend, 0, None)
}

/// Like [`run_backend`] but pinning the fresh client's worker-thread count
/// and SIMD selection (the process-global overrides are gone; settings live
/// on each client and are captured by its executables).
fn run_backend_with(
    comp: &XlaComputation,
    args: &[ArgData],
    backend: ShimBackend,
    threads: usize,
    simd: Option<bool>,
) -> RunOut {
    let client = PjRtClient::cpu().unwrap();
    client.set_threads(threads);
    client.set_simd(simd);
    let bufs = make_buffers(&client, args);
    let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
    let exe = client.compile_with_backend(comp, backend).map_err(|e| e.to_string())?;
    let mut out = exe.execute_b(&refs).map_err(|e| e.to_string())?;
    Ok(out
        .remove(0)
        .iter()
        .map(|b| fingerprint(&b.to_literal_sync().unwrap()))
        .collect())
}

/// Thread counts the bytecode backend is fuzzed over (the
/// `TERRA_SHIM_THREADS` axis, driven through the per-client override so
/// the process env stays untouched): the seed's single-threaded path, one
/// extra worker, and an oversubscribed pool.
const THREAD_AXIS: [usize; 3] = [1, 2, 8];

/// SIMD settings the bytecode backend is fuzzed over (the `TERRA_SHIM_SIMD`
/// axis, driven through the per-client override): the seed's scalar loops
/// and the explicit-width vector kernels, which must be indistinguishable
/// bit for bit.
const SIMD_AXIS: [bool; 2] = [false, true];

fn check_seed(seed: u64, allow_rng: bool) {
    let (comp, args) = build_case(seed, allow_rng);
    let rng_seed = 0x5EED_0000 ^ seed;
    xla::set_rng_state(rng_seed);
    let a = run_backend(&comp, &args, ShimBackend::Interp);
    let state_interp = xla::rng_state();
    // Every (thread count, SIMD setting) must reproduce the single-threaded
    // interp oracle bit for bit, RNG stream state included (draws stay on
    // the dispatch thread, never in the worker pool, and never vectorize).
    for simd in SIMD_AXIS {
        for threads in THREAD_AXIS {
            xla::set_rng_state(rng_seed);
            let c = run_backend_with(&comp, &args, ShimBackend::Bytecode, threads, Some(simd));
            let state_bytecode = xla::rng_state();
            match (&a, &c) {
                (Ok(a), Ok(c)) => {
                    assert_eq!(a.len(), c.len(), "output arity differs at seed {seed}");
                    for (j, (l, r)) in a.iter().zip(c.iter()).enumerate() {
                        assert_eq!(l.0, r.0, "output {j} dtype differs at seed {seed}");
                        assert_eq!(l.1, r.1, "output {j} dims differ at seed {seed}");
                        assert_eq!(
                            l.2, r.2,
                            "output {j} bits differ at seed {seed} \
                             (threads {threads}, simd {simd})"
                        );
                    }
                    if allow_rng {
                        assert_eq!(
                            state_interp, state_bytecode,
                            "RNG stream state diverged at seed {seed} \
                             (threads {threads}, simd {simd})"
                        );
                    }
                }
                (Err(_), Err(_)) => {} // both backends reject the graph: acceptable
                (a, c) => panic!(
                    "backend disagreement at seed {seed} (threads {threads}, simd {simd}): \
                     interp ok={}, bytecode ok={}",
                    a.is_ok(),
                    c.is_ok()
                ),
            }
        }
    }
}

/// The full fuzz sweep, RNG ops included. Runs serially in one test so the
/// process-global RNG stream cannot be interleaved by parallel tests.
#[test]
fn bytecode_matches_interpreter_over_generated_corpus() {
    for seed in 0..160 {
        check_seed(seed, true);
    }
}

/// Long elementwise chains: the fusion-heavy shape (PR 1's optimizer output
/// cashes out through exactly these segments).
#[test]
fn bytecode_matches_interpreter_on_elementwise_chains() {
    for seed in 0..40 {
        let mut rng = Rng::new(0xE1E_0000 + seed);
        let b = XlaBuilder::new("chain");
        let n = 16 + rng.below(64);
        let x = b.parameter(0, ElementType::F32, &[n as i64], "x").unwrap();
        let c = b.c0(rng.uniform(0.2, 1.5)).unwrap();
        let mut cur = x.clone();
        let depth = 4 + rng.below(24);
        for _ in 0..depth {
            cur = match rng.below(6) {
                0 => cur.tanh().unwrap(),
                1 => cur.logistic().unwrap(),
                2 => cur.neg().unwrap(),
                3 => cur.mul_(&c).unwrap(),
                4 => cur.add_(&x).unwrap(),
                _ => cur.abs().unwrap(),
            };
        }
        let comp = b.build(&cur).unwrap();
        let data = rng.normal_vec(n, 1.0);
        let args = vec![ArgData::F { data, dims: vec![n] }];
        let a = run_backend(&comp, &args, ShimBackend::Interp).unwrap();
        for simd in SIMD_AXIS {
            let cres =
                run_backend_with(&comp, &args, ShimBackend::Bytecode, 0, Some(simd)).unwrap();
            assert_eq!(a, cres, "chain seed {seed} diverged (simd {simd})");
        }
    }
}

/// Matmul sizes drawn from the bench_fig5 workloads, swept over the thread
/// axis: bitwise-identical accumulation (k-order and zero-skip preserved by
/// the blocked kernel; row partitioning never regroups a sum). The last two
/// sizes clear the parallel flop threshold.
#[test]
fn bytecode_matches_interpreter_on_matmul_sizes() {
    let sizes = [
        (4, 8, 4),
        (16, 16, 16),
        (32, 64, 8),
        (64, 32, 48),
        (1, 128, 1),
        (48, 96, 32),
        (96, 64, 96),
    ];
    for (m, k, n) in sizes {
        let mut rng = Rng::new((m * 1000 + k * 10 + n) as u64);
        let b = XlaBuilder::new("mm");
        let a = b.parameter(0, ElementType::F32, &[m, k], "a").unwrap();
        let bb = b.parameter(1, ElementType::F32, &[k, n], "b").unwrap();
        let mm = a.matmul(&bb).unwrap();
        let comp = b.build(&mm).unwrap();
        // Include exact zeros so the zero-skip path is exercised.
        let mut av = rng.normal_vec((m * k) as usize, 1.0);
        for i in (0..av.len()).step_by(7) {
            av[i] = 0.0;
        }
        let bv = rng.normal_vec((k * n) as usize, 1.0);
        let args = vec![
            ArgData::F { data: av, dims: vec![m as usize, k as usize] },
            ArgData::F { data: bv, dims: vec![k as usize, n as usize] },
        ];
        let x = run_backend(&comp, &args, ShimBackend::Interp).unwrap();
        for simd in SIMD_AXIS {
            for threads in THREAD_AXIS {
                let y = run_backend_with(&comp, &args, ShimBackend::Bytecode, threads, Some(simd))
                    .unwrap();
                assert_eq!(
                    x, y,
                    "matmul {m}x{k}x{n} diverged (threads {threads}, simd {simd})"
                );
            }
        }
    }
}

/// Shapes big enough that every parallel kernel genuinely dispatches to the
/// worker pool (the fuzz corpus shapes mostly sit below the thresholds):
/// fused chain, softmax, keep-dims and full reduces, and a batched matmul,
/// all bit-identical across the thread axis and to the interp oracle.
#[test]
fn parallel_kernels_match_oracle_on_large_shapes() {
    let b = XlaBuilder::new("parlarge");
    let x = b.parameter(0, ElementType::F32, &[128, 512], "x").unwrap();
    let w = b.parameter(1, ElementType::F32, &[512, 64], "w").unwrap();
    let c = b.c0(0.37f32).unwrap();
    let chain = x.mul_(&c).unwrap().tanh().unwrap().add_(&x).unwrap().logistic().unwrap();
    let sm = chain.softmax(1).unwrap();
    let mm = sm.matmul(&w).unwrap();
    let rsum = sm.reduce_sum(&[1], false).unwrap();
    let rmean = chain.reduce_mean(&[0], true).unwrap();
    let rmax = chain.reduce_max(&[0, 1], false).unwrap();
    let root = b.tuple(&[mm, rsum, rmean, rmax]).unwrap();
    let comp = b.build(&root).unwrap();

    let mut rng = Rng::new(0x9A55_1E57);
    let mut xv = rng.normal_vec(128 * 512, 1.2);
    for i in (0..xv.len()).step_by(11) {
        xv[i] = 0.0; // exercise the matmul zero-skip on the parallel path
    }
    let wv = rng.normal_vec(512 * 64, 0.8);
    let args = vec![
        ArgData::F { data: xv, dims: vec![128, 512] },
        ArgData::F { data: wv, dims: vec![512, 64] },
    ];
    let oracle = run_backend(&comp, &args, ShimBackend::Interp).unwrap();
    for simd in SIMD_AXIS {
        for threads in THREAD_AXIS {
            let got = run_backend_with(&comp, &args, ShimBackend::Bytecode, threads, Some(simd))
                .unwrap();
            assert_eq!(
                oracle, got,
                "large-shape parallel run diverged (threads {threads}, simd {simd})"
            );
        }
    }
}
