//! Three-layer integration: AOT Pallas/JAX artifacts on the rust request
//! path. Requires `make artifacts` (skips otherwise, so `cargo test` works
//! in a fresh checkout; `make test` always runs them).

use terra::config::ExecMode;
use terra::programs::build_program;
use terra::runner::Engine;

fn artifacts_available() -> Option<String> {
    let dir = std::env::var("TERRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn run(name: &str, mode: ExecMode, dir: &str, steps: u64) -> (Vec<(u64, f32)>, bool) {
    let mut engine = Engine::new(mode, dir, true).unwrap();
    let mut prog = build_program(name).unwrap();
    let report = engine.run(prog.as_mut(), steps, 0).unwrap();
    let used_artifact = engine.trace_graph().dump().contains("artifact:");
    (report.losses, used_artifact)
}

#[test]
fn fused_attention_kernel_runs_on_terra_hot_path() {
    let Some(dir) = artifacts_available() else { return };
    let steps = 8;
    let (eager, _) = run("bert_qa", ExecMode::Eager, &dir, steps);
    let (terra, used) = run("bert_qa", ExecMode::Terra, &dir, steps);
    assert!(used, "bert_qa must invoke the fused attention artifact");
    for ((s, a), (_, b)) in eager.iter().zip(terra.iter()) {
        assert!(
            (a - b).abs() <= 2e-4 * a.abs().max(1.0),
            "artifact-path numerics diverge at {s}: {a} vs {b}"
        );
    }
}

#[test]
fn attention_artifact_gradient_flows() {
    // The vjp artifact must produce real training signal: loss decreases.
    let Some(dir) = artifacts_available() else { return };
    let (losses, used) = run("bert_qa", ExecMode::Terra, &dir, 24);
    assert!(used);
    let first: f32 = losses[..4].iter().map(|(_, l)| l).sum::<f32>() / 4.0;
    let last: f32 = losses[losses.len() - 4..].iter().map(|(_, l)| l).sum::<f32>() / 4.0;
    assert!(last < first, "no learning through the fused kernel: {first} -> {last}");
}

#[test]
fn dropblock_mask_kernel_runs() {
    let Some(dir) = artifacts_available() else { return };
    let (losses, used) = run("dropblock", ExecMode::Terra, &dir, 10);
    assert!(used, "dropblock must invoke the Pallas mask kernel");
    assert!(losses.iter().all(|(_, l)| l.is_finite()));
}
