//! Flight-recorder contract tests (ISSUE 8): tracing must be free when off,
//! invisible when on, and the Chrome-trace export must carry the structure
//! the observability layer promises.
//!
//! - **Overhead guard**: with no trace config installed, a full Terra run
//!   records zero events; with tracing on, losses and final variables are
//!   *bit-identical* to the untraced run (recording never alters control
//!   flow, rendezvous order, or arithmetic).
//! - **Golden structure**: a traced `moe_router` run with an injected
//!   segment fault exports valid Chrome trace-event JSON with named
//!   PythonRunner/GraphRunner tracks, `segment_exec` spans nested inside
//!   their `graph_iter` span, fault/fallback instants, and a fault-dump
//!   file beside the trace.
//!
//! The recorder is process-global, so every test serializes on one lock and
//! restores the disabled state on exit (panic included) via `ObsReset`.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use terra::config::{ExecMode, Json};
use terra::faults::FaultPlan;
use terra::obs;
use terra::programs::{build_program, Program, TinyLinear};
use terra::runner::Engine;
use terra::speculate::{Quarantine, ReentryPolicy, SpeculateConfig};
use terra::tensor::HostTensor;

fn serialize() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(Mutex::default).lock().unwrap_or_else(PoisonError::into_inner)
}

/// Restores the recorder to its disabled, empty state on drop, so a failing
/// test cannot leak an installed config into the next one.
struct ObsReset;

impl Drop for ObsReset {
    fn drop(&mut self) {
        obs::install(None);
        obs::clear();
    }
}

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_obs_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

/// Plan cache off and eager re-entry: deterministic entry timing, same as
/// the fault-injection suite.
fn spec() -> SpeculateConfig {
    SpeculateConfig { plan_cache: false, policy: ReentryPolicy::Eager, split_hot_sites: false }
}

fn terra_engine(dir: &str) -> Engine {
    let mut engine = Engine::with_speculate(ExecMode::Terra, dir, false, 0, spec()).unwrap();
    engine.set_quarantine(Arc::new(Quarantine::with_max_faults(100)));
    engine.set_watchdog(None);
    engine
}

fn final_vars(engine: &Engine) -> Vec<HostTensor> {
    engine.vars().ids().into_iter().map(|id| engine.vars().host(id).unwrap()).collect()
}

fn run_tiny(dir: &str, steps: u64) -> (Vec<(u64, f32)>, Vec<HostTensor>) {
    let mut engine = terra_engine(dir);
    let mut prog = TinyLinear::new(0);
    let report = engine.run(&mut prog, steps, 0).unwrap();
    (report.losses, final_vars(&engine))
}

#[test]
fn disabled_tracing_records_nothing() {
    let _g = serialize();
    let _reset = ObsReset;
    std::env::remove_var("TERRA_TRACE");
    obs::install(None);
    obs::clear();
    let _ = run_tiny(&artifacts_dir(), 12);
    assert!(
        obs::events().is_empty(),
        "a run without a trace config must not record events (got {})",
        obs::events().len()
    );
    assert!(!obs::enabled());
}

#[test]
fn traced_run_is_bit_identical_to_untraced() {
    let _g = serialize();
    let _reset = ObsReset;
    std::env::remove_var("TERRA_TRACE");
    let dir = artifacts_dir();
    obs::install(None);
    obs::clear();
    let (plain_losses, plain_vars) = run_tiny(&dir, 23);

    let path = std::env::temp_dir().join("terra_obs_identical_trace.json");
    let cfg = obs::TraceConfig::parse("test", &format!("chrome:{}", path.display())).unwrap();
    obs::install(Some(cfg));
    obs::clear();
    let (traced_losses, traced_vars) = run_tiny(&dir, 23);

    assert!(!obs::events().is_empty(), "the traced run must record events");
    assert_eq!(plain_losses, traced_losses, "tracing changed the losses");
    assert_eq!(plain_vars, traced_vars, "tracing changed the final variables");
}

/// Chrome events are flat JSON objects; pull the fields the structure
/// assertions need. `ts`/`dur` stay in microseconds as written.
struct Ev {
    name: String,
    ph: String,
    tid: u64,
    ts: f64,
    dur: f64,
    iter: u64,
}

fn parse_events(doc: &Json) -> Vec<Ev> {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array")
        .iter()
        .map(|e| Ev {
            name: e.str_field("name").unwrap().to_string(),
            ph: e.str_field("ph").unwrap().to_string(),
            tid: e.get("tid").and_then(Json::as_f64).unwrap() as u64,
            ts: e.get("ts").and_then(Json::as_f64).unwrap_or(0.0),
            dur: e.get("dur").and_then(Json::as_f64).unwrap_or(0.0),
            iter: e
                .get("args")
                .and_then(|a| a.get("iter"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64,
        })
        .collect()
}

#[test]
fn golden_trace_structure_with_injected_fault() {
    let _g = serialize();
    let _reset = ObsReset;
    std::env::remove_var("TERRA_TRACE");
    let dir = std::env::temp_dir().join("terra_obs_golden");
    std::fs::create_dir_all(&dir).unwrap();
    // Stale dumps from a previous run of this binary would satisfy the
    // fault-dump assertion vacuously.
    for entry in std::fs::read_dir(&dir).unwrap().flatten() {
        let _ = std::fs::remove_file(entry.path());
    }
    let trace_path = dir.join("trace.json");
    let cfg =
        obs::TraceConfig::parse("test", &format!("chrome:{}", trace_path.display())).unwrap();
    obs::install(Some(cfg));
    obs::clear();

    // moe_router: dynamic control flow (expert switch every 8 steps) forces
    // divergence fallbacks; the injected segment error at iteration 2 forces
    // the fault → dump → imperative-replay path.
    let mut engine = terra_engine(&artifacts_dir());
    engine.set_fault_plan(Some(Arc::new(
        FaultPlan::parse("segment_exec:error:iter=2", 0).unwrap(),
    )));
    let mut prog: Box<dyn Program> = build_program("moe_router").unwrap();
    let report = engine.run(prog.as_mut(), 32, 0).unwrap();
    assert!(report.stats.faults_injected >= 1, "{:?}", report.stats);
    assert!(report.stats.enter_coexec >= 1, "{:?}", report.stats);

    let written = obs::export().unwrap().expect("a config is installed");
    let doc = Json::parse(&std::fs::read_to_string(&written).unwrap())
        .expect("exported trace must be valid JSON");
    let evs = parse_events(&doc);

    // Named runner tracks (Perfetto swim lanes).
    for (tid, name) in [(1u64, "PythonRunner"), (2, "GraphRunner")] {
        assert!(
            evs.iter().any(|e| {
                e.ph == "M" && e.name == "thread_name" && e.tid == tid
            }),
            "missing thread_name metadata for tid {tid} ({name})"
        );
        assert!(
            evs.iter().any(|e| e.ph != "M" && e.tid == tid),
            "no events recorded on the {name} track"
        );
    }

    // Every segment execution nests inside its iteration's graph_iter span
    // (1 µs tolerance: start/end are reconstructed from two monotonic reads).
    let iters: Vec<&Ev> = evs.iter().filter(|e| e.name == "graph_iter").collect();
    let segs: Vec<&Ev> = evs.iter().filter(|e| e.name == "segment_exec").collect();
    assert!(!iters.is_empty(), "no graph_iter spans");
    assert!(!segs.is_empty(), "no segment_exec spans");
    for seg in &segs {
        assert!(
            iters.iter().any(|it| it.iter == seg.iter
                && seg.ts + 1.0 >= it.ts
                && seg.ts + seg.dur <= it.ts + it.dur + 1.0),
            "segment_exec at iter {} (ts {:.3}) not nested in any graph_iter span",
            seg.iter,
            seg.ts
        );
    }

    // The fault ladder leaves its instants on the timeline: the injection,
    // the contained fault, the imperative replay of uncommitted steps, and
    // (from moe_router's expert switch) a divergence fallback.
    for name in ["fault_injected", "fault", "imperative_replay", "fallback"] {
        assert!(
            evs.iter().any(|e| e.ph == "i" && e.name == name),
            "missing `{name}` instant in the exported trace"
        );
    }
    // Both runners contribute nested span work under the engine's phases.
    for name in ["py_exec", "trace_exec", "enter_coexec", "plan_gen"] {
        assert!(
            evs.iter().any(|e| e.ph == "X" && e.name == name),
            "missing `{name}` span in the exported trace"
        );
    }

    // The contained fault dumped its timeline context next to the trace.
    let dumps: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .filter(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.starts_with("trace.json.fault") && n.ends_with(".json")
        })
        .collect();
    assert!(!dumps.is_empty(), "no fault-dump file written next to the trace");
    let dump = Json::parse(&std::fs::read_to_string(dumps[0].path()).unwrap())
        .expect("fault dump must be valid JSON");
    assert!(dump.str_field("stage").is_ok(), "dump missing `stage`");
    assert!(dump.str_field("message").is_ok(), "dump missing `message`");
    assert!(
        !dump.arr_field("events").unwrap().is_empty(),
        "fault dump carries no ring events"
    );
}
