//! Integration tests for the graph-optimization pipeline (`opt`): a program
//! with systematic redundancy must produce *identical* numerics at every
//! optimization level while the optimized plan compiles measurably less.

use terra::api::{Session, Variable};
use terra::config::ExecMode;
use terra::error::Result;
use terra::programs::{build_program, Program, StepOutput};
use terra::runner::{Engine, RunReport};
use terra::tensor::HostTensor;

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_opt_it_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

/// Training-loop shaped program with deliberate redundancy:
/// * the same matmul issued twice (CSE bait),
/// * `·1` and `−0` scalar ops (algebraic bait),
/// * an unused tanh branch (DCE bait).
struct RedundantProgram {
    w: Option<Variable>,
}

impl Program for RedundantProgram {
    fn name(&self) -> &'static str {
        "redundant_program"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        let init: Vec<f32> = (0..16).map(|i| ((i as f32) * 0.37).sin() * 0.5).collect();
        self.w = Some(sess.variable("w", HostTensor::f32(vec![4, 4], init)?, true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let data: Vec<f32> = (0..16)
            .map(|i| ((i as f32) + (step as f32) * 0.1).sin())
            .collect();
        let x = sess.feed(HostTensor::f32(vec![4, 4], data)?)?;
        let a = x.matmul(&w.read())?;
        let b = x.matmul(&w.read())?; // identical computation, new call site
        let c = a.add(&b)?;
        let d = c.mul_scalar(1.0)?; // identity
        let e = d.sub_scalar(0.0)?; // identity (x - (+0.0) is sign-exact)
        let _dead = e.tanh()?; // never fetched or assigned
        let loss = e.reduce_mean(&[0, 1], false)?;
        w.assign(&w.read().mul_scalar(0.999)?)?;
        Ok(StepOutput { loss: Some(loss), extra: vec![] })
    }
}

fn run_redundant(opt_level: u8, steps: u64) -> (RunReport, HostTensor) {
    let dir = artifacts_dir();
    let mut engine = Engine::with_opt_level(ExecMode::Terra, &dir, true, opt_level).unwrap();
    let mut prog = RedundantProgram { w: None };
    let report = engine.run(&mut prog, steps, 0).unwrap();
    let w = prog.w.as_ref().unwrap().id();
    (report, engine.vars().host(w).unwrap())
}

#[test]
fn optimized_plan_is_smaller_and_numerically_identical() {
    let steps = 12;
    let (r0, w0) = run_redundant(0, steps);
    let (r2, w2) = run_redundant(2, steps);

    // Both reach co-execution.
    assert!(r0.stats.enter_coexec >= 1, "{:?}", r0.stats);
    assert!(r2.stats.enter_coexec >= 1, "{:?}", r2.stats);

    // Semantics: identical losses and identical final weights.
    assert_eq!(r0.losses.len(), r2.losses.len());
    for ((s, a), (_, b)) in r0.losses.iter().zip(r2.losses.iter()) {
        assert!(
            (a - b).abs() <= 1e-6 * a.abs().max(1.0),
            "step {s}: opt0 {a} vs opt2 {b}"
        );
    }
    assert!(w0.allclose(&w2, 1e-6, 1e-7), "weights diverge across opt levels");

    // Payoff: the optimizer did real work and the plan compiles fewer op
    // nodes per iteration (acceptance criterion of the opt layer).
    assert_eq!(r0.stats.opt_nodes_removed, 0);
    assert!(r2.stats.opt_nodes_removed > 0, "{:?}", r2.stats);
    assert!(r2.stats.opt_rewrites > 0, "{:?}", r2.stats);
    assert!(
        r2.stats.plan_segment_nodes < r0.stats.plan_segment_nodes,
        "optimized plan must compile fewer segment nodes: opt2 {} vs opt0 {}",
        r2.stats.plan_segment_nodes,
        r0.stats.plan_segment_nodes
    );
    assert!(r2.opt.pipelines >= 1);
    assert!(r2.opt.last_nodes_after < r2.opt.last_nodes_before);
}

#[test]
fn dce_only_level_is_also_safe() {
    let steps = 10;
    let (r0, w0) = run_redundant(0, steps);
    let (r1, w1) = run_redundant(1, steps);
    for ((s, a), (_, b)) in r0.losses.iter().zip(r1.losses.iter()) {
        assert!(
            (a - b).abs() <= 1e-6 * a.abs().max(1.0),
            "step {s}: opt0 {a} vs opt1 {b}"
        );
    }
    assert!(w0.allclose(&w1, 1e-6, 1e-7));
    // DCE alone removes the dead tanh.
    assert!(r1.stats.opt_nodes_removed >= 1, "{:?}", r1.stats);
    assert_eq!(r1.stats.opt_nodes_folded, 0);
}

/// Transpose-heavy program: a two-hop transpose chain (composable to one
/// copy) plus a transpose/tanh/transpose sandwich whose permutations cancel
/// (collapsible to a bare tanh). Bait for the layout-assignment pass.
struct TransposeHeavyProgram;

impl Program for TransposeHeavyProgram {
    fn name(&self) -> &'static str {
        "transpose_heavy"
    }

    fn setup(&mut self, _sess: &Session) -> Result<()> {
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let data: Vec<f32> = (0..24)
            .map(|i| ((i as f32) * 0.7 + (step as f32) * 0.13).sin())
            .collect();
        let x = sess.feed(HostTensor::f32(vec![2, 3, 4], data)?)?;
        // Chain: two non-involutive transposes, net perm [2,0,1] (not id).
        let chain = x.transpose(&[1, 2, 0])?.transpose(&[1, 2, 0])?;
        // Sandwich: perms cancel ([1,2,0] then [2,0,1]), tanh commutes.
        let sandwich = x.transpose(&[1, 2, 0])?.tanh()?.transpose(&[2, 0, 1])?;
        let a = chain.reduce_mean(&[0, 1, 2], false)?;
        let b = sandwich.reduce_mean(&[0, 1, 2], false)?;
        let loss = a.add(&b)?;
        Ok(StepOutput { loss: Some(loss), extra: vec![] })
    }
}

#[test]
fn layout_pass_preserves_values_and_bounds_copies() {
    let dir = artifacts_dir();
    let run = |opt: u8| -> (RunReport, u64) {
        let before = xla::shim_totals().layout_copies_inserted;
        let mut engine = Engine::with_opt_level(ExecMode::Terra, &dir, true, opt).unwrap();
        let report = engine.run(&mut TransposeHeavyProgram, 12, 0).unwrap();
        (report, xla::shim_totals().layout_copies_inserted - before)
    };
    let (r0, copies0) = run(0);
    let (r2, _copies2) = run(2);
    assert!(r0.stats.enter_coexec >= 1, "{:?}", r0.stats);
    assert!(r2.stats.enter_coexec >= 1, "{:?}", r2.stats);

    // Pass off vs on: identical fetched losses (transposes and tanh are
    // exact, so even bit equality would hold; the engine API hands back
    // f32s, compared with the suite's standard tolerance).
    assert_eq!(r0.losses.len(), r2.losses.len());
    for ((s, a), (_, b)) in r0.losses.iter().zip(r2.losses.iter()) {
        assert!(
            (a - b).abs() <= 1e-6 * a.abs().max(1.0),
            "step {s}: layout off {a} vs on {b}"
        );
    }

    // The raw plan materializes one strided copy per transpose: the program
    // has four, so the counter moves by at least that much (a lower bound —
    // the counter is process-global, so concurrent tests may add to it).
    assert!(copies0 >= 4, "raw plan compiled only {copies0} layout copies");

    // The layout pass itself reports its work deterministically: one chain
    // composition plus one sandwich collapse, bounded by the chain count.
    let layout = r2
        .opt
        .per_pass
        .iter()
        .find(|(name, _)| *name == "layout")
        .map(|(_, s)| *s)
        .expect("layout pass ran at opt level 2");
    assert!(
        layout.rewrites >= 2,
        "expected the chain composition and the sandwich collapse, got {layout:?}"
    );
    assert!(
        layout.rewrites <= 2 * r2.opt.pipelines,
        "layout rewrites are bounded by the chain count per pipeline run: \
         {} rewrites over {} run(s)",
        layout.rewrites,
        r2.opt.pipelines
    );
    // With the chain composed and the sandwich collapsed, the optimized
    // plan compiles fewer op nodes overall.
    assert!(
        r2.stats.plan_segment_nodes < r0.stats.plan_segment_nodes,
        "optimized plan must shrink: opt2 {} vs opt0 {}",
        r2.stats.plan_segment_nodes,
        r0.stats.plan_segment_nodes
    );
}

#[test]
fn registry_program_identical_across_opt_levels() {
    let dir = artifacts_dir();
    let run = |opt: u8| -> Vec<(u64, f32)> {
        let mut engine = Engine::with_opt_level(ExecMode::Terra, &dir, true, opt).unwrap();
        let mut prog = build_program("tiny_linear").unwrap();
        engine.run(prog.as_mut(), 12, 0).unwrap().losses
    };
    let l0 = run(0);
    let l2 = run(2);
    assert_eq!(l0.len(), l2.len());
    for ((s, a), (_, b)) in l0.iter().zip(l2.iter()) {
        assert!(
            (a - b).abs() <= 1e-5 * a.abs().max(1.0),
            "tiny_linear step {s}: opt0 {a} vs opt2 {b}"
        );
    }
}
