//! End-to-end integration tests of the Terra engine: tracing phase,
//! transition to co-execution, fetch/feed/case-select communication,
//! divergence fallback, and eager-vs-Terra numerical equivalence
//! (DESIGN.md invariants 1 and 4).

use terra::api::Session;
use terra::config::ExecMode;
use terra::error::Result;
use terra::programs::{Program, StepOutput, TinyLinear};
use terra::runner::Engine;
use terra::tensor::HostTensor;

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_it_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

fn run_mode(mode: ExecMode, fusion: bool, steps: u64) -> (Vec<(u64, f32)>, HostTensor, terra::runner::EngineStats) {
    let dir = artifacts_dir();
    let mut engine = Engine::new(mode, &dir, fusion).unwrap();
    let mut prog = TinyLinear::new(5);
    let report = engine.run(&mut prog, steps, 0).unwrap();
    let w = prog.w.as_ref().unwrap().id();
    let w_final = engine.vars().host(w).unwrap();
    (report.losses, w_final, report.stats)
}

#[test]
fn terra_enters_coexecution_and_matches_eager() {
    let steps = 23;
    let (eager_losses, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (terra_losses, terra_w, stats) = run_mode(ExecMode::Terra, true, steps);

    assert!(stats.enter_coexec >= 1, "Terra must reach co-execution: {stats:?}");
    assert_eq!(eager_losses.len(), terra_losses.len());
    for ((s1, l1), (s2, l2)) in eager_losses.iter().zip(terra_losses.iter()) {
        assert_eq!(s1, s2);
        assert!((l1 - l2).abs() <= 1e-5 * l1.abs().max(1.0), "loss mismatch at {s1}: {l1} vs {l2}");
    }
    assert!(
        eager_w.allclose(&terra_w, 1e-5, 1e-6),
        "final weights diverge: {eager_w} vs {terra_w}"
    );
}

#[test]
fn terra_without_fusion_matches_eager() {
    let steps = 17;
    let (_, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (_, terra_w, stats) = run_mode(ExecMode::Terra, false, steps);
    assert!(stats.enter_coexec >= 1);
    assert!(eager_w.allclose(&terra_w, 1e-5, 1e-6));
}

#[test]
fn terra_lazy_matches_eager() {
    let steps = 19;
    let (_, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (_, lazy_w, stats) = run_mode(ExecMode::TerraLazy, true, steps);
    assert!(stats.enter_coexec >= 1);
    assert!(eager_w.allclose(&lazy_w, 1e-5, 1e-6));
}

/// A program that changes its op path at a given step — after Terra has
/// already entered co-execution — to exercise the divergence fallback.
struct PathSwitcher {
    w: Option<terra::api::Variable>,
    switch_at: u64,
}

impl Program for PathSwitcher {
    fn name(&self) -> &'static str {
        "path_switcher"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        self.w = Some(sess.variable("w", HostTensor::scalar_f32(1.0), true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let x = sess.feed(HostTensor::scalar_f32(0.5 + step as f32 * 0.01))?;
        let y = w.read().mul(&x)?;
        // Host-driven control flow the graph has never seen before:
        let z = if step >= self.switch_at { y.tanh()? } else { y.relu()? };
        w.assign(&z)?;
        Ok(StepOutput { loss: Some(z), extra: vec![] })
    }
}

/// Pure-eager oracle of the same computation.
fn oracle(steps: u64, switch_at: u64) -> f32 {
    let mut w = 1.0f32;
    for step in 0..steps {
        let x = 0.5 + step as f32 * 0.01;
        let y = w * x;
        w = if step >= switch_at { y.tanh() } else { y.max(0.0) };
    }
    w
}

#[test]
fn divergence_falls_back_and_stays_correct() {
    let dir = artifacts_dir();
    let steps = 16;
    let switch_at = 9; // Terra enters co-exec at step 2; diverges at 9.
    let mut engine = Engine::new(ExecMode::Terra, &dir, true).unwrap();
    let mut prog = PathSwitcher { w: None, switch_at };
    let report = engine.run(&mut prog, steps, 0).unwrap();
    assert!(report.stats.enter_coexec >= 2, "re-enters co-exec after fallback: {:?}", report.stats);
    assert!(report.stats.fallbacks >= 1, "must fall back at the switch: {:?}", report.stats);
    let w = prog.w.as_ref().unwrap().id();
    let w_final = engine.vars().host(w).unwrap().scalar_value_f32().unwrap();
    let expect = oracle(steps, switch_at);
    assert!(
        (w_final - expect).abs() < 1e-5,
        "fallback corrupted state: {w_final} vs oracle {expect}"
    );
}

#[test]
fn eager_and_terra_agree_on_multi_path_program() {
    // Fetch-every-5 makes two distinct iteration shapes; Terra must handle
    // the Switch correctly for many alternating iterations.
    let steps = 41;
    let (eager_losses, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (terra_losses, terra_w, stats) = run_mode(ExecMode::Terra, true, steps);
    assert_eq!(eager_losses, {
        // exact step indices match; values compared with tolerance below
        terra_losses.iter().map(|(s, _)| *s).zip(eager_losses.iter().map(|(_, l)| *l)).map(|(s, l)| (s, l)).collect::<Vec<_>>()
    });
    for ((_, l1), (_, l2)) in eager_losses.iter().zip(terra_losses.iter()) {
        assert!((l1 - l2).abs() <= 1e-5 * l1.abs().max(1.0));
    }
    assert!(eager_w.allclose(&terra_w, 1e-4, 1e-6));
    assert!(stats.enter_coexec >= 1);
}

/// Profile-guided segment splitting (ISSUE 4 tentpole): a program whose
/// co-execution diverges repeatedly at the *same* graph site (an MoE-style
/// expert switch: same call site, novel dataflow variant). After the site
/// gets hot the engine pre-splits plans there, so a later fallback at the
/// site truncates the in-flight iteration at the segment boundary — the
/// validated upstream segment survives, only downstream segments are
/// cancelled — while every committed upstream iteration and the replayed
/// step stay exactly on the eager oracle's trajectory.
#[test]
fn mid_plan_fallback_with_splitting_cancels_only_downstream() {
    use terra::programs::MoeRouter;
    use terra::speculate::{ReentryPolicy, SpeculateConfig};

    let dir = artifacts_dir();
    let steps = 40;
    let switch_every = 6; // expert switches at steps 6, 12, 18
    let spec = SpeculateConfig {
        plan_cache: true,
        // Eager re-entry makes the fallback schedule deterministic: the
        // engine is back in co-execution before every expert switch.
        policy: ReentryPolicy::Eager,
        split_hot_sites: true,
    };

    let run = |mode: ExecMode, spec: SpeculateConfig| {
        let mut engine = Engine::with_speculate(mode, &dir, true, 2, spec).unwrap();
        let mut prog = MoeRouter::new(switch_every);
        let report = engine.run(&mut prog, steps, 0).unwrap();
        let vars: Vec<HostTensor> = engine
            .vars()
            .ids()
            .into_iter()
            .map(|id| engine.vars().host(id).unwrap())
            .collect();
        (report, vars)
    };

    let (eager_report, eager_vars) = run(ExecMode::Eager, spec);
    let (report, vars) = run(ExecMode::Terra, spec);
    let stats = report.stats;

    // Each first use of a new expert diverges at the trunk's tanh node.
    assert!(stats.fallbacks >= 3, "expert switches must diverge: {stats:?}");
    // The first two fallbacks see un-split plans (the site is mid-segment):
    // whole-iteration cancels.
    assert!(stats.steps_cancelled >= 1, "{stats:?}");
    // By the third fallback the site is hot (count >= 2): the plan was
    // pre-split there, so the fallback truncated at the boundary and the
    // upstream trunk segment survived.
    assert!(
        stats.plan_split_points >= 1,
        "hot site must split the plan: {stats:?}"
    );
    assert!(
        stats.steps_saved_by_split >= 1,
        "a fallback at the split site must salvage the upstream segment: {stats:?}"
    );

    // Exactness: partial cancellation must not change observable results —
    // losses step for step and every variable (trunk + all four experts)
    // identical to the eager oracle.
    assert_eq!(eager_report.losses.len(), report.losses.len());
    for ((s1, l1), (s2, l2)) in eager_report.losses.iter().zip(report.losses.iter()) {
        assert_eq!(s1, s2);
        assert!(
            (l1 - l2).abs() <= 1e-5 * l1.abs().max(1.0),
            "loss mismatch at step {s1}: eager {l1} vs terra {l2}"
        );
    }
    assert_eq!(eager_vars.len(), vars.len());
    for (i, (a, b)) in eager_vars.iter().zip(vars.iter()).enumerate() {
        assert!(a.allclose(b, 1e-5, 1e-6), "var {i} mismatch: {a} vs {b}");
    }

    // The knob off = seed behaviour: same numerics, no splits, no salvage.
    let off = SpeculateConfig { split_hot_sites: false, ..spec };
    let (report_off, vars_off) = run(ExecMode::Terra, off);
    assert_eq!(report_off.stats.steps_saved_by_split, 0, "{:?}", report_off.stats);
    assert_eq!(report_off.stats.plan_split_points, 0, "{:?}", report_off.stats);
    for (a, b) in eager_vars.iter().zip(vars_off.iter()) {
        assert!(a.allclose(b, 1e-5, 1e-6), "split=off diverged from oracle");
    }
}
