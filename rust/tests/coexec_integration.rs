//! End-to-end integration tests of the Terra engine: tracing phase,
//! transition to co-execution, fetch/feed/case-select communication,
//! divergence fallback, and eager-vs-Terra numerical equivalence
//! (DESIGN.md invariants 1 and 4).

use terra::api::Session;
use terra::config::ExecMode;
use terra::error::Result;
use terra::programs::{Program, StepOutput, TinyLinear};
use terra::runner::Engine;
use terra::tensor::HostTensor;

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_it_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

fn run_mode(mode: ExecMode, fusion: bool, steps: u64) -> (Vec<(u64, f32)>, HostTensor, terra::runner::EngineStats) {
    let dir = artifacts_dir();
    let mut engine = Engine::new(mode, &dir, fusion).unwrap();
    let mut prog = TinyLinear::new(5);
    let report = engine.run(&mut prog, steps, 0).unwrap();
    let w = prog.w.as_ref().unwrap().id();
    let w_final = engine.vars().host(w).unwrap();
    (report.losses, w_final, report.stats)
}

#[test]
fn terra_enters_coexecution_and_matches_eager() {
    let steps = 23;
    let (eager_losses, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (terra_losses, terra_w, stats) = run_mode(ExecMode::Terra, true, steps);

    assert!(stats.enter_coexec >= 1, "Terra must reach co-execution: {stats:?}");
    assert_eq!(eager_losses.len(), terra_losses.len());
    for ((s1, l1), (s2, l2)) in eager_losses.iter().zip(terra_losses.iter()) {
        assert_eq!(s1, s2);
        assert!((l1 - l2).abs() <= 1e-5 * l1.abs().max(1.0), "loss mismatch at {s1}: {l1} vs {l2}");
    }
    assert!(
        eager_w.allclose(&terra_w, 1e-5, 1e-6),
        "final weights diverge: {eager_w} vs {terra_w}"
    );
}

#[test]
fn terra_without_fusion_matches_eager() {
    let steps = 17;
    let (_, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (_, terra_w, stats) = run_mode(ExecMode::Terra, false, steps);
    assert!(stats.enter_coexec >= 1);
    assert!(eager_w.allclose(&terra_w, 1e-5, 1e-6));
}

#[test]
fn terra_lazy_matches_eager() {
    let steps = 19;
    let (_, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (_, lazy_w, stats) = run_mode(ExecMode::TerraLazy, true, steps);
    assert!(stats.enter_coexec >= 1);
    assert!(eager_w.allclose(&lazy_w, 1e-5, 1e-6));
}

/// A program that changes its op path at a given step — after Terra has
/// already entered co-execution — to exercise the divergence fallback.
struct PathSwitcher {
    w: Option<terra::api::Variable>,
    switch_at: u64,
}

impl Program for PathSwitcher {
    fn name(&self) -> &'static str {
        "path_switcher"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        self.w = Some(sess.variable("w", HostTensor::scalar_f32(1.0), true)?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let x = sess.feed(HostTensor::scalar_f32(0.5 + step as f32 * 0.01))?;
        let y = w.read().mul(&x)?;
        // Host-driven control flow the graph has never seen before:
        let z = if step >= self.switch_at { y.tanh()? } else { y.relu()? };
        w.assign(&z)?;
        Ok(StepOutput { loss: Some(z), extra: vec![] })
    }
}

/// Pure-eager oracle of the same computation.
fn oracle(steps: u64, switch_at: u64) -> f32 {
    let mut w = 1.0f32;
    for step in 0..steps {
        let x = 0.5 + step as f32 * 0.01;
        let y = w * x;
        w = if step >= switch_at { y.tanh() } else { y.max(0.0) };
    }
    w
}

#[test]
fn divergence_falls_back_and_stays_correct() {
    let dir = artifacts_dir();
    let steps = 16;
    let switch_at = 9; // Terra enters co-exec at step 2; diverges at 9.
    let mut engine = Engine::new(ExecMode::Terra, &dir, true).unwrap();
    let mut prog = PathSwitcher { w: None, switch_at };
    let report = engine.run(&mut prog, steps, 0).unwrap();
    assert!(report.stats.enter_coexec >= 2, "re-enters co-exec after fallback: {:?}", report.stats);
    assert!(report.stats.fallbacks >= 1, "must fall back at the switch: {:?}", report.stats);
    let w = prog.w.as_ref().unwrap().id();
    let w_final = engine.vars().host(w).unwrap().scalar_value_f32().unwrap();
    let expect = oracle(steps, switch_at);
    assert!(
        (w_final - expect).abs() < 1e-5,
        "fallback corrupted state: {w_final} vs oracle {expect}"
    );
}

#[test]
fn eager_and_terra_agree_on_multi_path_program() {
    // Fetch-every-5 makes two distinct iteration shapes; Terra must handle
    // the Switch correctly for many alternating iterations.
    let steps = 41;
    let (eager_losses, eager_w, _) = run_mode(ExecMode::Eager, true, steps);
    let (terra_losses, terra_w, stats) = run_mode(ExecMode::Terra, true, steps);
    assert_eq!(eager_losses, {
        // exact step indices match; values compared with tolerance below
        terra_losses.iter().map(|(s, _)| *s).zip(eager_losses.iter().map(|(_, l)| *l)).map(|(s, l)| (s, l)).collect::<Vec<_>>()
    });
    for ((_, l1), (_, l2)) in eager_losses.iter().zip(terra_losses.iter()) {
        assert!((l1 - l2).abs() <= 1e-5 * l1.abs().max(1.0));
    }
    assert!(eager_w.allclose(&terra_w, 1e-4, 1e-6));
    assert!(stats.enter_coexec >= 1);
}
