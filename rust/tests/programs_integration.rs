//! Cross-cutting integration tests: every benchmark program must train under
//! both the imperative engine and Terra co-execution, with matching numerics
//! for the deterministic (RNG-free) programs.

use terra::config::ExecMode;
use terra::programs::build_program;
use terra::runner::Engine;

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_prog_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

/// Run a program for `steps` and return (losses, per-variable final values).
fn run(name: &str, mode: ExecMode, steps: u64) -> (Vec<(u64, f32)>, Vec<terra::tensor::HostTensor>, terra::runner::EngineStats) {
    let dir = artifacts_dir();
    let mut engine = Engine::new(mode, &dir, true).unwrap();
    let mut prog = build_program(name).unwrap();
    let report = engine
        .run(prog.as_mut(), steps, 0)
        .unwrap_or_else(|e| panic!("{name} under {mode:?} failed: {e}"));
    let vars: Vec<_> = engine
        .vars()
        .ids()
        .into_iter()
        .map(|id| engine.vars().host(id).unwrap())
        .collect();
    (report.losses, vars, report.stats)
}

fn check_program(name: &str, steps: u64, deterministic: bool) {
    let (el, ev, _) = run(name, ExecMode::Eager, steps);
    let (tl, tv, stats) = run(name, ExecMode::Terra, steps);
    assert!(stats.enter_coexec >= 1, "{name}: never entered co-execution: {stats:?}");
    assert!(el.iter().all(|(_, l)| l.is_finite()), "{name}: eager loss not finite");
    assert!(tl.iter().all(|(_, l)| l.is_finite()), "{name}: terra loss not finite");
    if deterministic {
        for ((s, a), (_, b)) in el.iter().zip(tl.iter()) {
            assert!(
                (a - b).abs() <= 2e-4 * a.abs().max(1.0),
                "{name}: loss diverges at step {s}: eager {a} vs terra {b}"
            );
        }
        assert_eq!(ev.len(), tv.len());
        for (i, (a, b)) in ev.iter().zip(tv.iter()).enumerate() {
            assert!(
                a.allclose(b, 5e-3, 1e-4),
                "{name}: final var {i} mismatch: {a} vs {b}"
            );
        }
    }
}

#[test]
fn resnet50_trains_identically() {
    check_program("resnet50", 8, true);
}

#[test]
fn dropblock_trains() {
    // Uses RNG dropout masks: numerics differ by construction.
    check_program("dropblock", 12, false);
}

#[test]
fn sdpoint_trains_identically() {
    check_program("sdpoint", 12, true);
}

#[test]
fn dcgan_trains() {
    check_program("dcgan", 8, false);
}

#[test]
fn yolov3_trains_identically() {
    check_program("yolov3", 8, true);
}

#[test]
fn faster_rcnn_trains_identically() {
    check_program("faster_rcnn", 8, true);
}

#[test]
fn bert_cls_trains_identically() {
    check_program("bert_cls", 8, true);
}

#[test]
fn bert_qa_trains_identically() {
    check_program("bert_qa", 8, true);
}

#[test]
fn gpt2_trains_identically_across_buckets() {
    // Buckets force several tracing<->coexec transitions.
    let (_, _, stats) = run("gpt2", ExecMode::Terra, 14);
    assert!(stats.enter_coexec >= 2, "gpt2 should retrace per bucket: {stats:?}");
    check_program("gpt2", 14, true);
}

#[test]
fn music_transformer_trains_identically() {
    check_program("music_transformer", 10, true);
}

#[test]
fn moe_router_trains_identically_across_expert_switches() {
    // Host-driven expert routing: each first use of a new expert (steps 8
    // and 16 with the registry's switch_every = 8) diverges at the same
    // trunk site and falls back.
    let (_, _, stats) = run("moe_router", ExecMode::Terra, 20);
    assert!(stats.fallbacks >= 1, "expert switch must diverge: {stats:?}");
    check_program("moe_router", 20, true);
}

#[test]
fn moe_router_trains_identically_on_interp_backend() {
    // The interpreter escape hatch must cover the dynamic-control-flow
    // workload too. The CI interp job runs the whole suite under
    // XLA_SHIM_BACKEND=interp; this pins the combination in the default job
    // as well. The knob is process-global, so concurrently running tests in
    // this binary may compile the odd segment on the interpreter while it
    // is set — harmless: the backends are bit-identical by contract, and
    // the segment caches key on the active backend (PR 4).
    let prev = std::env::var("XLA_SHIM_BACKEND").ok();
    std::env::set_var("XLA_SHIM_BACKEND", "interp");
    let result = std::panic::catch_unwind(|| {
        let (_, _, stats) = run("moe_router", ExecMode::Terra, 20);
        assert!(stats.fallbacks >= 1, "expert switch must diverge: {stats:?}");
        check_program("moe_router", 20, true);
    });
    match prev {
        Some(v) => std::env::set_var("XLA_SHIM_BACKEND", v),
        None => std::env::remove_var("XLA_SHIM_BACKEND"),
    }
    if let Err(p) = result {
        std::panic::resume_unwind(p);
    }
}

#[test]
fn losses_decrease_under_terra() {
    // Training sanity: first-vs-last loss for a deterministic program.
    let (losses, _, _) = run("resnet50", ExecMode::Terra, 20);
    let first = losses.first().unwrap().1;
    let last = losses.last().unwrap().1;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}
