//! Integration tests for the unified training path: a full train step
//! (forward + tape-generated gradient graph + fused optimizer update) flows
//! through the speculative plan pipeline like any forward trace.
//!
//! Covers the ISSUE acceptance criteria:
//! * a repeated train step re-enters from the plan cache — second engine
//!   instance sees `plan_cache_hits > 0`, `segments_compiled == 0`, and the
//!   gradient-specific counters (`grad_plan_cache_hits`, `optim_steps_fused`)
//!   are live end to end;
//! * under an injected mid-run segment panic, truncated steps drop parameter
//!   AND Adam-moment updates atomically: the run stays bit-identical to the
//!   pure-eager oracle (fusion off / opt 0, the single-op-kernel contract
//!   from `fault_injection.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;

use terra::config::ExecMode;
use terra::faults::FaultPlan;
use terra::programs::{TrainMlp, TrainOptim};
use terra::runner::{Engine, RunReport};
use terra::speculate::{PlanCache, Quarantine, ReentryPolicy, SpeculateConfig};

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_train_it_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        std::fs::write(manifest, r#"{"artifacts": []}"#).unwrap();
    }
    dir.to_string_lossy().into_owned()
}

/// All committed variable buffers — parameters, Adam moments, step counter —
/// keyed by name, as exact bit patterns.
fn var_bits(engine: &Engine) -> BTreeMap<String, Vec<u32>> {
    let mut out = BTreeMap::new();
    for id in engine.vars().ids() {
        let name = engine.vars().meta(id).unwrap().name;
        let host = engine.vars().host(id).unwrap();
        out.insert(name, host.as_f32().unwrap().iter().map(|f| f.to_bits()).collect());
    }
    out
}

fn loss_bits(report: &RunReport) -> Vec<(u64, u32)> {
    report.losses.iter().map(|(s, l)| (*s, l.to_bits())).collect()
}

/// The tentpole acceptance test: two engine instances sharing one plan cache.
/// The first traces, compiles and caches the merged train-step plan; the
/// second replays the identical iteration shape and must be served entirely
/// from the cache — no optimizer pass, no segment compilation — while the
/// gradient-path counters confirm what was reused was a *training* plan.
#[test]
fn repeated_train_step_reenters_from_plan_cache() {
    let steps = 12;
    let spec = SpeculateConfig {
        plan_cache: true,
        policy: ReentryPolicy::Eager,
        split_hot_sites: false,
    };
    let cache = Arc::new(PlanCache::with_capacity(16));

    let run = |cache: &Arc<PlanCache>| {
        let dir = artifacts_dir();
        let mut engine = Engine::with_speculate(ExecMode::Terra, &dir, true, 2, spec).unwrap();
        engine.set_plan_cache(Some(cache.clone()));
        engine.set_quarantine(Arc::new(Quarantine::with_max_faults(2)));
        engine.loss_every = 1;
        let mut prog = TrainMlp::new(TrainOptim::Adam, true);
        let report = engine.run(&mut prog, steps, 0).unwrap();
        let bits = var_bits(&engine);
        (report, bits)
    };

    // First instance: compiles the train-step plan and populates the cache.
    let (r1, w1) = run(&cache);
    assert!(r1.stats.enter_coexec >= 1, "{:?}", r1.stats);
    assert!(r1.stats.segments_compiled > 0, "first instance must compile: {:?}", r1.stats);
    assert!(
        r1.stats.optim_steps_fused > 0,
        "co-executed steps must run the optimizer inside the plan: {:?}",
        r1.stats
    );

    // Second instance: the identical train step re-enters without any
    // compilation, and the hit is attributed to the gradient path.
    let (r2, w2) = run(&cache);
    let s2 = r2.stats;
    assert!(s2.enter_coexec >= 1, "{s2:?}");
    assert!(s2.plan_cache_hits > 0, "re-entry must be a cache hit: {s2:?}");
    assert_eq!(s2.plan_cache_misses, 0, "{s2:?}");
    assert_eq!(s2.segments_compiled, 0, "no fresh segment compiles on re-entry: {s2:?}");
    assert_eq!(s2.plans_generated, 0, "plan generation skipped entirely: {s2:?}");
    assert!(
        s2.grad_plan_cache_hits > 0,
        "the reused plan carries the gradient graph: {s2:?}"
    );
    assert!(s2.optim_steps_fused > 0, "{s2:?}");

    // Both instances trained identically: deterministic data + deterministic
    // init means every buffer (params, adam.m*/adam.v*, adam.t) matches.
    assert_eq!(loss_bits(&r1), loss_bits(&r2), "loss trajectories must match");
    assert_eq!(w1, w2, "final variable buffers must match");
    assert!(w1.keys().any(|k| k.starts_with("adam.m")), "moment slots must exist: {w1:?}");
}

/// The atomicity acceptance test: a segment panic injected mid-run truncates
/// an iteration; the staged-assign commit barrier must drop that iteration's
/// parameter and Adam-moment updates together, and the replayed run must end
/// bit-identical to a pure-eager oracle — losses, parameters and moment
/// buffers alike.
#[test]
fn fused_train_step_is_bit_identical_to_eager_under_segment_panic() {
    let steps = 12;
    let spec = SpeculateConfig {
        plan_cache: false,
        policy: ReentryPolicy::Eager,
        split_hot_sites: false,
    };

    // Fusion off, opt 0: every plan node is the same single-op shim kernel
    // the eager executor uses, making bitwise comparison valid.
    let run = |mode: ExecMode, faults: Option<&str>| {
        let dir = artifacts_dir();
        let mut engine = Engine::with_speculate(mode, &dir, false, 0, spec).unwrap();
        engine.set_quarantine(Arc::new(Quarantine::with_max_faults(2)));
        engine.set_fault_plan(faults.map(|f| Arc::new(FaultPlan::parse(f, 7).unwrap())));
        engine.set_watchdog(None);
        engine.loss_every = 1;
        let mut prog = TrainMlp::new(TrainOptim::Adam, true);
        let report = engine.run(&mut prog, steps, 0).unwrap();
        let bits = var_bits(&engine);
        (report, bits)
    };

    let (oracle_rep, oracle_bits) = run(ExecMode::Eager, None);
    let (faulted_rep, faulted_bits) = run(ExecMode::Terra, Some("segment_exec:panic:iter=5"));

    assert!(
        faulted_rep.stats.faults_injected > 0,
        "the panic must actually fire: {:?}",
        faulted_rep.stats
    );
    assert_eq!(
        loss_bits(&oracle_rep),
        loss_bits(&faulted_rep),
        "losses must match the eager oracle bit for bit"
    );
    assert_eq!(
        oracle_bits, faulted_bits,
        "params and Adam moments must match the eager oracle bit for bit \
         (truncated steps drop both atomically)"
    );
    assert!(
        oracle_bits.keys().any(|k| k.starts_with("adam.v")),
        "second-moment slots must be part of the comparison: {oracle_bits:?}"
    );
}
