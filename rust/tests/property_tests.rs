//! Property tests (hand-rolled generators; proptest is unavailable offline)
//! over the coordinator invariants listed in DESIGN.md §4:
//!
//! 1. eager/Terra numerical equivalence on random RNG-free programs,
//! 2. TraceGraph merge soundness & idempotence on random trace families,
//! 3. case-assignment totality: every merged trace replays through the
//!    walker with a consistent case/variant assignment,
//! 4. fallback safety under randomized path switching.

use std::sync::Arc;
use terra::api::{Session, Variable};
use terra::config::ExecMode;
use terra::data::Rng;
use terra::error::Result;
use terra::ops::{OpDef, OpKind};
use terra::programs::{Program, StepOutput};
use terra::runner::Engine;
use terra::tensor::{HostTensor, TensorType};
use terra::tracegraph::{GraphSrc, NodeId, TraceGraph, Walker};
use terra::trace::{FeedKind, Location, ResolvedSrc, Trace, TraceItem, ValueId, ValueRef};

fn artifacts_dir() -> String {
    let dir = std::env::temp_dir().join("terra_prop_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    dir.to_string_lossy().into_owned()
}

// ---------------------------------------------------------------------------
// Random trace generator: builds families of traces that share structure but
// branch at random positions (like real multi-path programs).
// ---------------------------------------------------------------------------

fn loc(line: u32) -> Location {
    Location { file: "prop.rs", line, col: 1, scope: 0 }
}

/// A random linear trace of unary ops over one feed; `branch_lines` lets two
/// traces share everything except chosen positions.
fn random_trace(rng: &mut Rng, len: usize, variant: u32) -> Trace {
    let mut items = vec![TraceItem::Feed {
        id: ValueId(1),
        ty: TensorType::f32(&[4]),
        loc: loc(1),
        kind: FeedKind::Data,
    }];
    let mut next = 2u64;
    for i in 0..len {
        // 20% of positions are variant-dependent (different op kind/loc).
        let variant_dependent = rng.below(5) == 0;
        let kinds = [OpKind::Relu, OpKind::Tanh, OpKind::Neg, OpKind::Abs];
        let kind = if variant_dependent {
            kinds[(variant as usize + rng.below(2)) % kinds.len()].clone()
        } else {
            kinds[rng.below(kinds.len())].clone()
        };
        let line = if variant_dependent { 1000 + i as u32 * 10 + variant } else { 10 + i as u32 };
        items.push(TraceItem::Op {
            def: OpDef::new(kind, vec![TensorType::f32(&[4])]),
            loc: loc(line),
            inputs: vec![ValueRef::Out(ValueId(next - 1))],
            outputs: vec![ValueId(next)],
        });
        next += 1;
    }
    items.push(TraceItem::Fetch { src: ValueRef::Out(ValueId(next - 1)), loc: loc(9999) });
    Trace::resolve(items, 0).unwrap()
}

fn replay(graph: &Arc<TraceGraph>, t: &Trace) -> Result<()> {
    let mut w = Walker::new(graph.clone());
    let mut node_of: Vec<NodeId> = Vec::with_capacity(t.len());
    for (i, item) in t.items.iter().enumerate() {
        let srcs: Vec<GraphSrc> = t.resolved[i]
            .iter()
            .map(|r| match r {
                ResolvedSrc::Var(v) => GraphSrc::Var(*v),
                ResolvedSrc::Item(p) => GraphSrc::Node { node: node_of[p.item], slot: p.slot },
            })
            .collect();
        let ev = w.advance(&item.key(), &srcs)?;
        node_of.push(ev.node);
    }
    w.finish()?;
    Ok(())
}

#[test]
fn prop_merge_is_idempotent_and_replayable() {
    for seed in 0..25u64 {
        let mut gen_rng = Rng::new(seed);
        let len = 4 + gen_rng.below(40);
        // A family of up to 4 structural variants.
        let n_variants = 1 + gen_rng.below(3) as u32;
        let traces: Vec<Trace> = (0..=n_variants)
            .map(|v| {
                // Regenerate with a per-variant rng derived from the seed so
                // shared positions match exactly.
                let mut r = Rng::new(seed);
                random_trace(&mut r, len, v)
            })
            .collect();
        let mut g = TraceGraph::new();
        for t in &traces {
            g.merge(t).unwrap();
        }
        // Invariant 2a: re-merging any covered trace changes nothing.
        for t in &traces {
            let rep = g.merge(t).unwrap();
            assert!(!rep.changed, "seed {seed}: re-merge changed the graph: {rep:?}");
        }
        // Invariant 2b: the graph stays a DAG with a valid topo order.
        g.topo_order().unwrap_or_else(|e| panic!("seed {seed}: cyclic graph: {e}"));
        // Invariant 3: every member of the family replays cleanly.
        let g = Arc::new(g);
        for (i, t) in traces.iter().enumerate() {
            replay(&g, t).unwrap_or_else(|e| panic!("seed {seed}: trace {i} diverged: {e}"));
        }
    }
}

#[test]
fn prop_unmerged_variant_diverges() {
    for seed in 100..115u64 {
        let mut r0 = Rng::new(seed);
        let len = 6 + r0.below(30);
        let t0 = {
            let mut r = Rng::new(seed);
            random_trace(&mut r, len, 0)
        };
        let t9 = {
            let mut r = Rng::new(seed);
            random_trace(&mut r, len, 9)
        };
        let mut g = TraceGraph::new();
        g.merge(&t0).unwrap();
        let g = Arc::new(g);
        // A structurally different variant must be detected, never silently
        // executed (unless the generator produced no variant positions).
        if t9.items.iter().map(|i| i.key()).ne(t0.items.iter().map(|i| i.key())) {
            assert!(replay(&g, &t9).is_err(), "seed {seed}: novel trace not detected");
        }
    }
}

// ---------------------------------------------------------------------------
// Random programs: eager vs Terra equivalence (invariant 1) and fallback
// safety under random path switching (invariant 4).
// ---------------------------------------------------------------------------

struct RandomProgram {
    seed: u64,
    w: Option<Variable>,
    n_layers: usize,
    n_paths: usize,
}

impl Program for RandomProgram {
    fn name(&self) -> &'static str {
        "random_program"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        let mut rng = Rng::new(self.seed);
        self.w = Some(sess.variable(
            "w",
            HostTensor::f32(vec![4, 4], rng.normal_vec(16, 0.4))?,
            true,
        )?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let w = self.w.as_ref().unwrap();
        let mut rng = Rng::for_step(self.seed, step);
        let x = sess.feed(HostTensor::f32(vec![4, 4], rng.normal_vec(16, 1.0))?)?;
        let tape = terra::tape::Tape::start(sess)?;
        let mut h = x.matmul(&w.read())?;
        // Host-driven random path: which activations run this step.
        let path = rng.below(self.n_paths);
        for i in 0..self.n_layers {
            h = match (i + path) % 3 {
                0 => h.relu()?,
                1 => h.tanh()?,
                _ => h.abs()?.add_scalar(1.0)?.log()?,
            };
        }
        let loss = h.mul(&h)?.reduce_mean(&[0, 1], false)?;
        let grads = tape.gradient(&loss, &[w])?;
        w.assign(&w.read().sub(&grads[0].mul_scalar(0.01)?)?)?;
        Ok(StepOutput { loss: Some(loss), extra: vec![] })
    }
}

#[test]
fn prop_random_programs_match_eager() {
    let dir = artifacts_dir();
    for seed in 0..6u64 {
        let steps = 14;
        let run = |mode: ExecMode| -> (Vec<(u64, f32)>, HostTensor) {
            let mut engine = Engine::new(mode, &dir, true).unwrap();
            let mut prog = RandomProgram {
                seed,
                w: None,
                n_layers: 2 + (seed as usize % 3),
                n_paths: 1 + (seed as usize % 3),
            };
            let report = engine.run(&mut prog, steps, 0).unwrap();
            let w = prog.w.as_ref().unwrap().id();
            (report.losses, engine.vars().host(w).unwrap())
        };
        let (el, ew) = run(ExecMode::Eager);
        let (tl, tw) = run(ExecMode::Terra);
        for ((s, a), (_, b)) in el.iter().zip(tl.iter()) {
            assert!(
                (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                "seed {seed} step {s}: {a} vs {b}"
            );
        }
        assert!(ew.allclose(&tw, 1e-4, 1e-5), "seed {seed}: weights diverge");
    }
}

// ---------------------------------------------------------------------------
// Optimization-pipeline properties: the `opt` passes must be semantics-
// preserving by construction (ISSUE: opt_level=0 and opt_level=2 produce
// numerically identical fetch results and variable states), and must
// preserve every wire-format index space on random graphs.
// ---------------------------------------------------------------------------

/// A random DAG-shaped trace: ops consume random earlier values (so some
/// values go dead), and a random subset of values is fetched.
fn random_dag_trace(rng: &mut Rng, len: usize) -> Trace {
    let mut items = vec![TraceItem::Feed {
        id: ValueId(1),
        ty: TensorType::f32(&[4]),
        loc: loc(1),
        kind: FeedKind::Data,
    }];
    let mut produced = vec![1u64];
    let mut next = 2u64;
    for i in 0..len {
        let src = produced[rng.below(produced.len())];
        let kinds = [OpKind::Relu, OpKind::Tanh, OpKind::Neg, OpKind::Abs];
        let kind = kinds[rng.below(kinds.len())].clone();
        items.push(TraceItem::Op {
            def: OpDef::new(kind, vec![TensorType::f32(&[4])]),
            loc: loc(10 + i as u32),
            inputs: vec![ValueRef::Out(ValueId(src))],
            outputs: vec![ValueId(next)],
        });
        produced.push(next);
        next += 1;
    }
    for j in 0..1 + rng.below(3) {
        let src = produced[rng.below(produced.len())];
        items.push(TraceItem::Fetch {
            src: ValueRef::Out(ValueId(src)),
            loc: loc(2000 + j as u32),
        });
    }
    Trace::resolve(items, 0).unwrap()
}

#[test]
fn prop_opt_pipeline_preserves_wire_format_invariants() {
    use terra::graphgen::{generate_plan, GenOptions};
    use terra::opt::PassManager;
    use terra::tracegraph::NodeKind;
    use terra::trace::ItemKey;
    use std::collections::HashMap;

    for seed in 300..330u64 {
        let mut rng = Rng::new(seed);
        let mut g = TraceGraph::new();
        let n_traces = 1 + rng.below(3);
        for k in 0..n_traces {
            let len = 4 + rng.below(24);
            // Half the traces replay a shared stream (prefix-sharing, trip-
            // count-style tail branches); the rest are independent (sibling
            // branches, cross-branch variants, merge-backs at shared locs).
            let trace_seed = if k % 2 == 0 { seed ^ 0xabc } else { seed ^ (k as u64 * 7919) };
            let mut r = Rng::new(trace_seed);
            g.merge(&random_dag_trace(&mut r, len)).unwrap();
        }
        let mut opt = g.clone();
        let report = PassManager::standard(2).run(&mut opt, None).unwrap();
        // Still a DAG.
        opt.topo_order().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(opt.live_len() <= g.live_len());
        assert_eq!(report.nodes_after, opt.live_len());
        for (i, n) in g.nodes.iter().enumerate() {
            let o = &opt.nodes[i];
            // Protected nodes (communication points) survive with their ids.
            let protected = matches!(
                &n.kind,
                NodeKind::Start
                    | NodeKind::End
                    | NodeKind::Item(ItemKey::Feed { .. })
                    | NodeKind::Item(ItemKey::Fetch { .. })
                    | NodeKind::Item(ItemKey::Assign { .. })
            ) || n.generalized;
            if protected {
                assert!(!o.removed, "seed {seed}: protected node {i} removed");
            }
            if !o.removed {
                // Case-Select arity: child count never changes on survivors.
                assert_eq!(
                    o.children.len(),
                    n.children.len(),
                    "seed {seed}: node {i} child count changed"
                );
                // Variant-Select arity: variant count never changes either
                // (no folding happens without an evaluator).
                assert_eq!(
                    o.variants.len(),
                    n.variants.len(),
                    "seed {seed}: node {i} variant count changed"
                );
            }
        }
        // Both graphs still generate plans, and the optimized one keeps all
        // communication steps.
        let opts = GenOptions { fusion: true, ..Default::default() };
        let p_raw = generate_plan(&g, &HashMap::new(), &opts).unwrap();
        let p_opt = generate_plan(&opt, &HashMap::new(), &opts).unwrap();
        let c_raw = terra::symbolic::PlanSpec::count_steps(&p_raw.steps);
        let c_opt = terra::symbolic::PlanSpec::count_steps(&p_opt.steps);
        assert_eq!(c_raw.1, c_opt.1, "seed {seed}: feed steps changed");
        assert_eq!(c_raw.2, c_opt.2, "seed {seed}: fetch steps changed");
        assert_eq!(c_raw.3, c_opt.3, "seed {seed}: assign steps changed");
    }
}

#[test]
fn prop_opt_levels_produce_identical_results() {
    // ISSUE acceptance: for randomly generated programs, opt_level=0 and
    // opt_level=2 yield numerically identical fetches and variable states.
    let dir = artifacts_dir();
    for seed in 40..46u64 {
        let steps = 14;
        let run = |opt: u8| -> (Vec<(u64, f32)>, HostTensor) {
            let mut engine = Engine::with_opt_level(ExecMode::Terra, &dir, true, opt).unwrap();
            let mut prog = RandomProgram {
                seed,
                w: None,
                n_layers: 2 + (seed as usize % 3),
                n_paths: 1 + (seed as usize % 3),
            };
            let report = engine.run(&mut prog, steps, 0).unwrap();
            let w = prog.w.as_ref().unwrap().id();
            (report.losses, engine.vars().host(w).unwrap())
        };
        let (l0, w0) = run(0);
        let (l2, w2) = run(2);
        assert_eq!(l0.len(), l2.len(), "seed {seed}: loss counts differ");
        for ((s, a), (_, b)) in l0.iter().zip(l2.iter()) {
            assert!(
                (a - b).abs() <= 1e-5 * a.abs().max(1.0),
                "seed {seed} step {s}: opt0 {a} vs opt2 {b}"
            );
        }
        assert!(w0.allclose(&w2, 1e-5, 1e-6), "seed {seed}: variable states diverge");
    }
}

#[test]
fn prop_fallbacks_never_corrupt_state() {
    // Heavily multi-path program: every step may diverge; weights must still
    // track the eager oracle exactly (staged-commit safety).
    let dir = artifacts_dir();
    for seed in 20..24u64 {
        let steps = 20;
        let run = |mode: ExecMode| -> (HostTensor, terra::runner::EngineStats) {
            let mut engine = Engine::new(mode, &dir, true).unwrap();
            let mut prog = RandomProgram { seed, w: None, n_layers: 3, n_paths: 3 };
            let report = engine.run(&mut prog, steps, 0).unwrap();
            let w = prog.w.as_ref().unwrap().id();
            (engine.vars().host(w).unwrap(), report.stats)
        };
        let (ew, _) = run(ExecMode::Eager);
        let (tw, stats) = run(ExecMode::Terra);
        assert!(
            ew.allclose(&tw, 1e-4, 1e-5),
            "seed {seed}: weights diverge after {} fallbacks",
            stats.fallbacks
        );
    }
}
