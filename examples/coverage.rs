//! Table 1 reproduction: run every benchmark program under the AutoGraph
//! baseline and under Terra, reporting which fail and why.
//!
//!     cargo run --release --example coverage

use terra::config::ExecMode;
use terra::error::TerraError;
use terra::programs::{all_program_names, build_program, expected_autograph_failure};
use terra::runner::Engine;

fn main() {
    let artifacts = std::env::var("TERRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps = 12;
    let mut rows = Vec::new();
    for name in all_program_names() {
        let autograph = {
            let result = Engine::new(ExecMode::AutoGraph, &artifacts, true)
                .and_then(|mut e| build_program(name).and_then(|mut p| e.run(p.as_mut(), steps, 0)));
            match result {
                Ok(_) => "ok".to_string(),
                Err(TerraError::Convert { category, .. }) => format!("FAIL: {category}"),
                Err(e) => format!("error: {e}"),
            }
        };
        let terra = {
            let result = Engine::new(ExecMode::Terra, &artifacts, true)
                .and_then(|mut e| build_program(name).and_then(|mut p| e.run(p.as_mut(), steps, 0)));
            match result {
                Ok(_) => "ok".to_string(),
                Err(e) => format!("error: {e}"),
            }
        };
        let paper = match expected_autograph_failure(name) {
            Some(cat) => format!("FAIL: {cat}"),
            None => "ok".to_string(),
        };
        rows.push(vec![name.to_string(), autograph, paper, terra]);
    }
    terra::bench::print_table(
        "Table 1 — program coverage: AutoGraph baseline vs Terra",
        &["program", "autograph (measured)", "autograph (paper)", "terra"],
        &rows,
    );
    let matches = rows.iter().filter(|r| r[1] == r[2]).count();
    println!("\n{matches}/{} programs match the paper's Table 1 outcome", rows.len());
}
