//! Lazy evaluation vs co-execution, live (the Table-2 story): run the same
//! program under Terra and under Terra-with-serialized-runners (LazyTensor
//! semantics) and print the runner breakdown of each.
//!
//!     cargo run --release --example serve_like_lazy -- [program]

use terra::config::ExecMode;
use terra::error::Result;
use terra::programs::build_program;
use terra::runner::Engine;

fn main() -> Result<()> {
    let program = std::env::args().nth(1).unwrap_or_else(|| "bert_qa".to_string());
    let artifacts = std::env::var("TERRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps = 40;
    let warmup = 20;

    let mut rows = Vec::new();
    for mode in [ExecMode::Eager, ExecMode::Terra, ExecMode::TerraLazy] {
        let mut engine = Engine::new(mode, &artifacts, true)?;
        let mut prog = build_program(&program)?;
        let report = engine.run(prog.as_mut(), steps, warmup)?;
        let b = report.breakdown_per_step;
        rows.push(vec![
            mode.name().to_string(),
            format!("{:.2}", report.steps_per_sec),
            format!("{:.2}", b.py_exec_ms),
            format!("{:.2}", b.py_stall_ms),
            format!("{:.2}", b.graph_exec_ms),
            format!("{:.2}", b.graph_stall_ms),
        ]);
    }
    terra::bench::print_table(
        &format!("{program}: co-execution vs lazy evaluation"),
        &["mode", "steps/s", "py exec ms", "py stall ms", "graph exec ms", "graph stall ms"],
        &rows,
    );
    println!(
        "\nLazy evaluation serializes the runners: the GraphRunner only starts when a value \
         is demanded, so the PythonRunner's time is no longer hidden (paper Table 2)."
    );
    Ok(())
}
