//! Lazy evaluation vs co-execution, live (the Table-2 story), served through
//! the multi-tenant runtime: each mode runs as one [`terra::serve::Session`]
//! on its own [`terra::serve::Runtime`] (a fresh runtime per mode keeps the
//! plan cache cold, so every mode pays its own compiles), and the runner
//! breakdown of each is printed.
//!
//!     cargo run --release --example serve_like_lazy -- [program]
//!
//! Obs events from each run carry the session's id, so a `--trace` capture
//! of this example separates the modes into their own Chrome-trace lanes.

use terra::config::{ExecMode, RunConfig};
use terra::error::Result;
use terra::programs::build_program;
use terra::serve::Runtime;

fn main() -> Result<()> {
    let program = std::env::args().nth(1).unwrap_or_else(|| "bert_qa".to_string());
    let steps = 40;
    let warmup = 20;

    let mut cfg = RunConfig { program: program.clone(), ..RunConfig::default() };

    let mut rows = Vec::new();
    for mode in [ExecMode::Eager, ExecMode::Terra, ExecMode::TerraLazy] {
        cfg.mode = mode;
        let rt = Runtime::with_defaults()?;
        let mut sess = rt.open_session(&cfg)?;
        let mut prog = build_program(&program)?;
        let report = sess.run(prog.as_mut(), steps, warmup)?;
        let b = report.breakdown_per_step;
        rows.push(vec![
            mode.name().to_string(),
            format!("{:.2}", report.steps_per_sec),
            format!("{:.2}", b.py_exec_ms),
            format!("{:.2}", b.py_stall_ms),
            format!("{:.2}", b.graph_exec_ms),
            format!("{:.2}", b.graph_stall_ms),
        ]);
    }
    terra::bench::print_table(
        &format!("{program}: co-execution vs lazy evaluation"),
        &["mode", "steps/s", "py exec ms", "py stall ms", "graph exec ms", "graph stall ms"],
        &rows,
    );
    println!(
        "\nLazy evaluation serializes the runners: the GraphRunner only starts when a value \
         is demanded, so the PythonRunner's time is no longer hidden (paper Table 2)."
    );
    println!(
        "To serve many tenants from one process instead, share a single Runtime and open \
         one session per tenant: `terra serve --sessions N --budget M`."
    );
    Ok(())
}
