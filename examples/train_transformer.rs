//! End-to-end validation driver (DESIGN.md §6): train a decoder-style
//! transformer LM on a synthetic zipfian corpus through the **full Terra
//! pipeline** — imperative program → tracing → TraceGraph → runtime-compiled
//! fused plan → co-execution with the fused Pallas attention artifact on the
//! hot path — and log the loss curve.
//!
//!     make artifacts && cargo run --release --example train_transformer -- [steps] [--eager] [--large]
//!
//! Default: ~0.1M-parameter encoder LM, 300 steps (fits the 1-core CPU
//! testbed); `--large` scales dim/blocks up for bigger machines.

use terra::api::{Session, Variable};
use terra::config::ExecMode;
use terra::data::Rng;
use terra::error::Result;
use terra::nn::{softmax_cross_entropy, Dense, HasVars, Optimizer, Sgd};
use terra::programs::common::{Transformer, TransformerConfig};
use terra::programs::{Program, StepOutput};
use terra::runner::Engine;

const SEED: u64 = 0xe2e;

struct EncoderLm {
    cfg: TransformerConfig,
    batch: usize,
    model: Option<Transformer>,
    lm: Option<Dense>,
    opt: Sgd,
}

impl EncoderLm {
    fn new(large: bool) -> Self {
        let mut cfg = TransformerConfig::tiny(64, 16);
        if large {
            cfg.dim = 128;
            cfg.heads = 4;
            cfg.blocks = 4;
        }
        EncoderLm { cfg, batch: 4, model: None, lm: None, opt: Sgd::new(0.05) }
    }
}

impl Program for EncoderLm {
    fn name(&self) -> &'static str {
        "train_transformer"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        let mut rng = Rng::new(SEED);
        let model = Transformer::new(sess, "lm", self.cfg.clone(), &mut rng)?;
        let lm = Dense::new(sess, "lm_head", self.cfg.dim, self.cfg.vocab, false, &mut rng)?;
        let n_params: usize = model
            .vars()
            .iter()
            .chain(lm.vars().iter())
            .map(|v| v.ty().shape.num_elements())
            .sum();
        println!(
            "model: dim={} heads={} blocks={} -> {n_params} parameters",
            self.cfg.dim, self.cfg.heads, self.cfg.blocks
        );
        self.model = Some(model);
        self.lm = Some(lm);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let seq = self.cfg.max_seq;
        let ids = sess.feed(terra::data::token_batch(SEED, step, self.batch, seq, self.cfg.vocab))?;
        let model = self.model.as_ref().unwrap();
        let lm = self.lm.as_ref().unwrap();
        let mut vars = model.vars();
        vars.extend(lm.vars());
        let tape = terra::tape::Tape::start(sess)?;
        // Non-causal encoder (masked-LM style: predict shifted tokens from
        // full context) so the fused Pallas attention artifact is eligible.
        let h = model.forward(&ids, false)?;
        let logits = lm.forward(&h)?;
        let b = self.batch;
        let pred = logits
            .slice(&[0, 0, 0], &[b, seq - 1, self.cfg.vocab])?
            .reshape(&[b * (seq - 1), self.cfg.vocab])?;
        let target = ids.slice(&[0, 1], &[b, seq - 1])?.reshape(&[b * (seq - 1)])?;
        let loss = softmax_cross_entropy(&pred, &target)?;
        let refs: Vec<&Variable> = vars.iter().collect();
        let grads = tape.gradient(&loss, &refs)?;
        self.opt.apply(sess, &vars, &grads)?;
        Ok(StepOutput { loss: Some(loss), extra: vec![] })
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.iter().find_map(|a| a.parse().ok()).unwrap_or(300);
    let eager = args.iter().any(|a| a == "--eager");
    let large = args.iter().any(|a| a == "--large");
    let artifacts = std::env::var("TERRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mode = if eager { ExecMode::Eager } else { ExecMode::Terra };

    println!("training for {steps} steps under {} ...", mode.name());
    let mut engine = Engine::new(mode, &artifacts, true)?;
    let mut prog = EncoderLm::new(large);
    let report = engine.run(&mut prog, steps, steps.min(40) / 2)?;

    println!("\nloss curve (every 20 steps):");
    for (s, l) in report.losses.iter().filter(|(s, _)| s % 20 == 0) {
        println!("  step {s:>4}: loss {l:.4}");
    }
    let first = report.losses.first().map(|(_, l)| *l).unwrap_or(f32::NAN);
    let last = report.losses.last().map(|(_, l)| *l).unwrap_or(f32::NAN);
    println!("\n{}", report.summary());
    println!(
        "loss {first:.4} -> {last:.4}  ({} transitions, {} fallbacks, {} fused segments compiled, {} fused optimizer steps)",
        report.stats.enter_coexec,
        report.stats.fallbacks,
        report.stats.segments_compiled,
        report.stats.optim_steps_fused
    );
    let used_kernel = engine.trace_graph().dump().contains("artifact:attn_fwd");
    println!("fused Pallas attention on hot path: {used_kernel}");
    if mode == ExecMode::Terra {
        // The unified training path: once co-execution is entered, the SGD
        // update runs as staged assigns inside the compiled plan.
        assert!(
            report.stats.optim_steps_fused > 0,
            "Terra mode must execute fused optimizer steps: {:?}",
            report.stats
        );
    }
    Ok(())
}
