//! Quickstart: write an imperative DL program, run it eagerly, then hand the
//! *same unmodified program* to Terra and get symbolic-execution speed.
//!
//!     make artifacts && cargo run --release --example quickstart

use terra::api::{Session, Variable};
use terra::config::ExecMode;
use terra::error::Result;
use terra::programs::{Program, StepOutput};
use terra::runner::Engine;
use terra::tensor::HostTensor;

/// An ordinary imperative program: a 2-layer MLP on synthetic data, with a
/// host-side print (materialization) every 10 steps — the kind of harmless
/// Python-ism that breaks graph converters but not Terra.
struct Mlp {
    w1: Option<Variable>,
    w2: Option<Variable>,
}

impl Program for Mlp {
    fn name(&self) -> &'static str {
        "quickstart_mlp"
    }

    fn setup(&mut self, sess: &Session) -> Result<()> {
        let mut rng = terra::data::Rng::new(7);
        self.w1 = Some(sess.variable(
            "w1",
            HostTensor::f32(vec![16, 32], rng.normal_vec(16 * 32, 0.25))?,
            true,
        )?);
        self.w2 = Some(sess.variable(
            "w2",
            HostTensor::f32(vec![32, 1], rng.normal_vec(32, 0.25))?,
            true,
        )?);
        Ok(())
    }

    fn step(&mut self, sess: &Session, step: u64) -> Result<StepOutput> {
        let x = sess.feed(terra::data::image_batch(7, step, 8, 1, 4, 4))?;
        let x = x.reshape(&[8, 16])?;
        let target = sess.feed(terra::data::label_batch(7, step, 8, 2))?.convert(terra::tensor::DType::F32)?;
        let target = target.reshape(&[8, 1])?;

        let (w1, w2) = (self.w1.as_ref().unwrap(), self.w2.as_ref().unwrap());
        let tape = terra::tape::Tape::start(sess)?;
        let h = x.matmul(&w1.read())?.relu()?;
        let pred = h.matmul(&w2.read())?;
        let loss = terra::nn::mse(&pred, &target)?;
        let grads = tape.gradient(&loss, &[w1, w2])?;
        for (v, g) in [w1, w2].iter().zip(&grads) {
            v.assign(&v.read().sub(&g.mul_scalar(0.05)?)?)?;
        }

        if step % 10 == 0 {
            // Mid-step materialization: fine under Terra (Output Fetching).
            println!("  step {step}: |pred| = {:.4}", pred.abs()?.reduce_mean(&[0, 1], false)?.scalar_f32()?);
        }
        Ok(StepOutput { loss: Some(loss), extra: vec![] })
    }
}

fn main() -> Result<()> {
    let artifacts = std::env::var("TERRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps = 60;

    println!("== imperative (eager) execution ==");
    let mut eager = Engine::new(ExecMode::Eager, &artifacts, true)?;
    let r1 = eager.run(&mut Mlp { w1: None, w2: None }, steps, steps / 2)?;
    println!("{}", r1.summary());

    println!("\n== Terra imperative-symbolic co-execution (same program) ==");
    let mut terra = Engine::new(ExecMode::Terra, &artifacts, true)?;
    let r2 = terra.run(&mut Mlp { w1: None, w2: None }, steps, steps / 2)?;
    println!("{}", r2.summary());

    println!(
        "\nTerra speedup over imperative: {:.2}x  (losses agree: eager {:.5} vs terra {:.5})",
        r2.steps_per_sec / r1.steps_per_sec.max(1e-9),
        r1.losses.last().map(|(_, l)| *l).unwrap_or(f32::NAN),
        r2.losses.last().map(|(_, l)| *l).unwrap_or(f32::NAN),
    );
    Ok(())
}
