"""Kernel-vs-oracle correctness: the CORE numeric signal of the L1 layer.

Hypothesis sweeps shapes; assert_allclose against the pure-jnp references.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, dropblock, layernorm, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 8]),
    s=st.sampled_from([4, 12, 16]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_attention_matches_ref(bh, s, d, seed):
    q = rand(seed, (bh, s, d))
    k = rand(seed + 1, (bh, s, d))
    v = rand(seed + 2, (bh, s, d))
    out = attention.attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_attention_rows_are_convex_combinations():
    # softmax rows sum to 1 => each output row lies in conv hull of v rows
    q = rand(0, (2, 8, 16), scale=3.0)
    k = rand(1, (2, 8, 16), scale=3.0)
    v = jnp.ones((2, 8, 16), jnp.float32)
    out = attention.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.ones_like(out), rtol=1e-5)


def test_attention_vjp_matches_autodiff_of_ref():
    q, k, v = (rand(i, (2, 6, 8)) for i in range(3))
    g = rand(7, (2, 6, 8))
    got = attention.attention_vjp(q, k, v, g)
    _, pullback = jax.vjp(ref.attention_ref, q, k, v)
    want = pullback(g)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([8, 16, 64]),
    d=st.sampled_from([8, 32]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_layernorm_matches_ref(n, d, seed):
    x = rand(seed, (n, d), scale=2.0)
    gamma = rand(seed + 1, (d,)) + 1.0
    beta = rand(seed + 2, (d,))
    out = layernorm.layernorm(x, gamma, beta)
    want = ref.layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_layernorm_output_is_normalized():
    x = rand(3, (16, 32), scale=5.0)
    out = layernorm.layernorm(x, jnp.ones((32,)), jnp.zeros((32,)))
    mean = np.asarray(jnp.mean(out, axis=-1))
    np.testing.assert_allclose(mean, np.zeros_like(mean), atol=1e-4)


# ---------------------------------------------------------------------------
# dropblock mask
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.sampled_from([1, 4]),
    c=st.sampled_from([2, 8]),
    hw=st.sampled_from([2, 4]),
    gamma=st.floats(min_value=0.0, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_dropblock_mask_matches_ref(b, c, hw, gamma, seed):
    noise = jax.random.uniform(jax.random.PRNGKey(seed), (b, c, hw, hw), jnp.float32)
    g = jnp.float32(gamma)
    out = dropblock.dropblock_mask(noise, g)
    want = ref.dropblock_mask_ref(noise, g)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_dropblock_mask_is_binary():
    noise = jax.random.uniform(jax.random.PRNGKey(0), (4, 8, 4, 4), jnp.float32)
    out = np.asarray(dropblock.dropblock_mask(noise, jnp.float32(0.3)))
    assert set(np.unique(out)).issubset({0.0, 1.0})


def test_dropblock_gamma_zero_keeps_everything():
    noise = jax.random.uniform(jax.random.PRNGKey(1), (2, 2, 4, 4), jnp.float32)
    out = np.asarray(dropblock.dropblock_mask(noise, jnp.float32(0.0)))
    assert out.min() == 1.0
