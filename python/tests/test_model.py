"""L2 model checks: encoder-block shapes, determinism and differentiability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _run(b=2, s=8, d=32, heads=2, seed=0):
    p = model.encoder_block_params(d, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, d), jnp.float32)
    y = model.encoder_block(x, heads=heads, **p)
    return x, y, p


def test_block_preserves_shape():
    x, y, _ = _run()
    assert y.shape == x.shape
    assert y.dtype == jnp.float32


def test_block_is_deterministic():
    _, y1, _ = _run(seed=3)
    _, y2, _ = _run(seed=3)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_block_is_differentiable_through_kernel():
    p = model.encoder_block_params(32)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 8, 32), jnp.float32)

    def loss(wq):
        q = dict(p)
        q["wq"] = wq
        return jnp.sum(model.encoder_block(x, heads=2, **q) ** 2)

    g = jax.grad(loss)(p["wq"])
    assert g.shape == p["wq"].shape
    assert bool(jnp.any(g != 0.0))


def test_residual_identity_at_zero_weights():
    p = model.encoder_block_params(32)
    zeros = {k: jnp.zeros_like(v) for k, v in p.items()}
    # keep LN affine neutral so the residual path dominates
    zeros["g1"] = p["g1"]
    zeros["b1"] = p["b1"]
    zeros["g2"] = p["g2"]
    zeros["b2"] = p["b2"]
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 32), jnp.float32)
    y = model.encoder_block(x, heads=2, **zeros)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)
