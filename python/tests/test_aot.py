"""AOT pipeline checks: HLO text emission and manifest integrity."""

import json
import os
import tempfile

import jax

from compile import aot

jax.config.update("jax_platform_name", "cpu")


def test_hlo_text_emission_smoke():
    import jax.numpy as jnp

    text = aot.to_hlo_text(lambda x: (x * 2.0,), [aot.spec((2, 2))])
    assert "HloModule" in text
    # Interchange contract: text, never serialized protos (64-bit-id issue).
    assert text.strip()
    _ = jnp  # silence


def test_manifest_entries_are_consistent():
    entries = aot.build_manifest()
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names)), "duplicate artifact names"
    by_name = {e["name"]: e for e in entries}
    for e in entries:
        meta = e["entry"]
        assert len(e["args"]) == len(meta["in"])
        if "vjp" in meta:
            bwd = by_name[meta["vjp"]]["entry"]
            # vjp convention: inputs = fwd inputs ++ out cotangents,
            # outputs = one cotangent per fwd input.
            assert bwd["in"] == meta["in"] + meta["out"]
            assert bwd["out"] == meta["in"]


def test_full_lowering_roundtrip(tmp_path=None):
    out = tempfile.mkdtemp(prefix="terra_aot_test_")
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", out]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest["artifacts"]) >= 7
    for entry in manifest["artifacts"]:
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path), f"missing {entry['file']}"
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head
