"""L2: JAX model layer — the compute graphs that get AOT-lowered.

Build-time only: these functions are traced by jax, lowered to HLO text by
``aot.py``, and executed from rust via PJRT. Python never runs on the
request path.

The transformer encoder block here mirrors ``rust/src/programs/common.rs``
(pre-LN, 2x FFN) and calls the L1 Pallas attention kernel, so lowering it
exercises the full L2→L1 stack; pytest checks its shapes and numerics.
"""

import jax
import jax.numpy as jnp

from .kernels import attention as attn
from .kernels import ref


def encoder_block(x, wq, wk, wv, wo, g1, b1, g2, b2, w1, bb1, w2, bb2, heads):
    """Pre-LN transformer encoder block over [B, S, D], fused attention core."""
    b, s, d = x.shape
    dh = d // heads

    h = ref.layernorm_ref(x.reshape(b * s, d), g1, b1).reshape(b, s, d)
    q = h @ wq
    k = h @ wk
    v = h @ wv

    def split(t):
        return t.reshape(b, s, heads, dh).transpose(0, 2, 1, 3).reshape(b * heads, s, dh)

    ctx = attn.attention(split(q), split(k), split(v))
    ctx = ctx.reshape(b, heads, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + ctx @ wo

    h = ref.layernorm_ref(x.reshape(b * s, d), g2, b2).reshape(b, s, d)
    h = jax.nn.relu(h @ w1 + bb1)
    x = x + h @ w2 + bb2
    return x


def encoder_block_params(d, key=None):
    """Deterministic parameter pytree for shape tests / lowering examples."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    scale = 0.02
    return dict(
        wq=jax.random.normal(ks[0], (d, d), jnp.float32) * scale,
        wk=jax.random.normal(ks[1], (d, d), jnp.float32) * scale,
        wv=jax.random.normal(ks[2], (d, d), jnp.float32) * scale,
        wo=jax.random.normal(ks[3], (d, d), jnp.float32) * scale,
        g1=jnp.ones((d,), jnp.float32),
        b1=jnp.zeros((d,), jnp.float32),
        g2=jnp.ones((d,), jnp.float32),
        b2=jnp.zeros((d,), jnp.float32),
        w1=jax.random.normal(ks[4], (d, 2 * d), jnp.float32) * scale,
        bb1=jnp.zeros((2 * d,), jnp.float32),
        w2=jax.random.normal(ks[5], (2 * d, d), jnp.float32) * scale,
        bb2=jnp.zeros((d,), jnp.float32),
    )
