"""AOT pipeline: lower every artifact in the manifest to HLO *text*.

HLO text, not ``.serialize()``: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla_extension 0.5.1 the rust `xla` crate links
against rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import attention as attn_k
from .kernels import dropblock as db_k
from .kernels import layernorm as ln_k


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sig(shape):
    return f"f32[{','.join(str(d) for d in shape)}]"


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def attention_entries(bh, s, d):
    """Fused attention fwd (Pallas) + bwd (vjp of the oracle)."""
    io3 = [sig((bh, s, d))] * 3
    fwd_name = f"attn_fwd_bh{bh}_s{s}_d{d}"
    bwd_name = f"attn_bwd_bh{bh}_s{s}_d{d}"
    fwd = dict(
        name=fwd_name,
        fn=lambda q, k, v: (attn_k.attention(q, k, v),),
        args=[spec((bh, s, d))] * 3,
        entry={"in": io3, "out": [sig((bh, s, d))], "vjp": bwd_name},
    )
    bwd = dict(
        name=bwd_name,
        fn=lambda q, k, v, g: tuple(attn_k.attention_vjp(q, k, v, g)),
        args=[spec((bh, s, d))] * 4,
        entry={"in": io3 + [sig((bh, s, d))], "out": io3},
    )
    return [fwd, bwd]


def dropblock_entry(b, c, h, w):
    name = f"dropblock_mask_b{b}_c{c}_h{h}_w{w}"
    return dict(
        name=name,
        fn=lambda noise, gamma: (db_k.dropblock_mask(noise, gamma),),
        args=[spec((b, c, h, w)), spec(())],
        # The mask is piecewise-constant: no gradient flows through it
        # (like the RNG ops); the tape treats it as a stop-gradient.
        entry={"in": [sig((b, c, h, w)), "f32[]"], "out": [sig((b, c, h, w))], "nondiff": True},
    )


def layernorm_entries(n, d):
    fwd_name = f"layernorm_fwd_n{n}_d{d}"
    bwd_name = f"layernorm_bwd_n{n}_d{d}"
    fwd = dict(
        name=fwd_name,
        fn=lambda x, g, b: (ln_k.layernorm(x, g, b),),
        args=[spec((n, d)), spec((d,)), spec((d,))],
        entry={
            "in": [sig((n, d)), sig((d,)), sig((d,))],
            "out": [sig((n, d))],
            "vjp": bwd_name,
        },
    )
    bwd = dict(
        name=bwd_name,
        fn=lambda x, g, b, ct: tuple(ln_k.layernorm_vjp(x, g, b, ct)),
        args=[spec((n, d)), spec((d,)), spec((d,)), spec((n, d))],
        entry={
            "in": [sig((n, d)), sig((d,)), sig((d,)), sig((n, d))],
            "out": [sig((n, d)), sig((d,)), sig((d,))],
        },
    )
    return [fwd, bwd]


def build_manifest():
    """Every artifact the rust programs / examples can invoke.

    Shapes mirror rust/src/programs: dim 32, 2 heads (dh=16), batch 4
    (BH=8); sequence lengths 12 (BERT) and 16 (the E2E encoder example);
    the DropBlock mask operates on the post-conv1 8x8 feature map at block
    resolution 4x4 with 8 channels.
    """
    entries = []
    for s in (12, 16):
        entries += attention_entries(bh=8, s=s, d=16)
    entries.append(dropblock_entry(b=4, c=8, h=4, w=4))
    entries += layernorm_entries(n=64, d=32)
    return entries


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for e in build_manifest():
        fname = f"{e['name']}.hlo.txt"
        text = to_hlo_text(e["fn"], e["args"])
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entry = {"name": e["name"], "file": fname}
        entry.update(e["entry"])
        manifest.append(entry)
        print(f"  lowered {e['name']} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=1)
    print(f"wrote {len(manifest)} artifacts to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
