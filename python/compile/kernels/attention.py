"""L1: fused scaled-dot-product attention as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the original GPU
formulation tiles Q/K/V across threadblocks with shared-memory staging; on
TPU the same insight — keep the S×S score tile resident in fast memory and
fuse matmul→softmax→matmul — maps to a VMEM-resident block per (batch·head)
grid step feeding the MXU. BlockSpec carves one [1, S, D] slab of each
operand per grid step; for the miniature shapes (S ≤ 16, D = 16) the whole
working set (3·S·D + S·S floats ≈ 4 KB) sits comfortably in VMEM; the
EXPERIMENTS.md §Perf entry scales this budget analytically to the paper's
production shapes.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated through the interpreter against
``ref.attention_ref`` and the real-TPU path is compile-only.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[0, :, :]  # [S, D] VMEM tile
    k = k_ref[0, :, :]
    v = v_ref[0, :, :]
    # MXU matmul, f32 accumulate.
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Numerically stable softmax, fused in-register.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, :, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.custom_vjp
def attention(q, k, v):
    """Fused attention over [BH, S, D]; one grid step per batch·head."""
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_attn_kernel, scale=scale)
    block = pl.BlockSpec((1, s, d), lambda b: (b, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[block, block, block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def _attention_fwd(q, k, v):
    return attention(q, k, v), (q, k, v)


def _attention_bwd(residuals, g):
    q, k, v = residuals
    return tuple(attention_vjp(q, k, v, g))


attention.defvjp(_attention_fwd, _attention_bwd)


def attention_vjp(q, k, v, g):
    """Backward artifact body: cotangents for (q, k, v).

    Lowered from the jnp reference (the kernel matches it bit-for-bit under
    the interpreter, see tests), following the repo convention: vjp inputs =
    forward inputs ++ output cotangents.
    """
    from . import ref

    _, pullback = jax.vjp(ref.attention_ref, q, k, v)
    return pullback(g)
