"""L1: DropBlock keep-mask generation as a Pallas kernel.

The original CUDA DropBlock materializes a Bernoulli seed mask and dilates
it with a max-pool; our miniature drops aligned 2x2 blocks, so the mask is
computed directly on the block-resolution noise grid: keep = noise >= gamma.
Fused elementwise compare + cast over a VMEM tile, one grid step per batch.

The drop probability arrives as a runtime scalar (it is *mutated host
state* in the DropBlock program — the paper's Fig. 1c), so it is an input,
never a compile-time constant.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mask_kernel(noise_ref, gamma_ref, o_ref):
    noise = noise_ref[0]  # [C, H, W] tile for this batch element
    gamma = gamma_ref[0, 0]
    o_ref[0] = (noise >= gamma).astype(jnp.float32)


def dropblock_mask(noise, gamma):
    """noise: [B, C, H, W] uniforms; gamma: f32[] scalar -> keep mask."""
    b, c, h, w = noise.shape
    gamma2 = jnp.reshape(gamma, (1, 1)).astype(jnp.float32)
    return pl.pallas_call(
        _mask_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, h, w), jnp.float32),
        interpret=True,
    )(noise, gamma2)
