"""Pure-jnp reference oracles for the Pallas kernels.

These definitions are the correctness contract: every Pallas kernel must
match its oracle to float32 tolerance (pytest + hypothesis sweeps), and the
backward artifacts are lowered from ``jax.vjp`` of these references.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v):
    """Scaled-dot-product attention over [BH, S, D] tensors."""
    d = q.shape[-1]
    scores = jnp.einsum("bsd,btd->bst", q, k) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Layer norm over the last axis of [N, D]."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


def dropblock_mask_ref(noise, gamma):
    """Block-keep mask: 1.0 where noise >= gamma (noise in [0,1))."""
    return (noise >= gamma).astype(jnp.float32)
