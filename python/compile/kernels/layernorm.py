"""L1: fused layer normalization as a Pallas kernel.

One grid step per row-block: mean/variance/normalize/affine fused in VMEM —
the TPU rethink of the paper-era fused-layernorm CUDA kernels (single pass,
no shared-memory tree reductions; the VPU reduces a VMEM-resident tile).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]  # [BLOCK, D]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    centered = x - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = centered * inv * g_ref[...] + b_ref[...]


def layernorm(x, gamma, beta, eps=1e-5, block_rows=8):
    """Fused LN over the last axis of [N, D]; N must divide by block_rows."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)


def layernorm_vjp(x, gamma, beta, g):
    from . import ref

    _, pullback = jax.vjp(lambda a, gm, bt: ref.layernorm_ref(a, gm, bt), x, gamma, beta)
    return pullback(g)
